"""HLO-parser and hardware-spec tests for launch/roofline.py.

The parser is exercised on small hand-written HLO snippets so each rule —
while trip-count expansion, dot/convolution FLOP counting, collective
wire-bytes classification — is pinned independently of any compiled
artifact."""
import numpy as np
import pytest

from repro.core.hwspec import HardwareSpec, TPU_V5E
from repro.launch import roofline as RL


DOT_HLO = """
ENTRY %main (p0: f32[8,16], p1: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16] parameter(0)
  %p1 = f32[16,4] parameter(1)
  ROOT %d = f32[8,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_flops_from_contracting_dims():
    tot = RL.analyze_hlo(DOT_HLO, 1)
    # 2 * |result| * contract = 2 * (8*4) * 16
    assert tot.flops == 2.0 * 8 * 4 * 16 == 1024.0


def test_dot_bytes_at_boundaries():
    tot = RL.analyze_hlo(DOT_HLO, 1)
    # parameters are free; the dot reads both operands and writes its result
    assert tot.bytes == 4 * (8 * 16 + 16 * 4 + 8 * 4)


WHILE_HLO = """
%body (x: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  %a = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant(0)
  %d = f32[8,16] dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %d)
}

%cond (x: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,16]) -> (s32[], f32[8,16]) {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %a)
  ROOT %w0 = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body
}
"""


def test_while_body_expanded_by_trip_count():
    tot = RL.analyze_hlo(WHILE_HLO, 1)
    # the body's dot (2 * 8*16 * 16 = 4096 FLOPs) runs 5 times — XLA's own
    # cost_analysis would report it once
    assert tot.flops == 5 * 2.0 * 8 * 16 * 16


def test_trip_count_parses_comparison_constant():
    mod = RL.HloModule(WHILE_HLO)
    assert mod.trip_count("cond") == 5
    assert mod.entry == "main"


CONV_HLO = """
ENTRY %main (x: f32[1,8,8,4], k: f32[3,3,4,8]) -> f32[1,8,8,8] {
  %x = f32[1,8,8,4] parameter(0)
  %k = f32[3,3,4,8] parameter(1)
  ROOT %c = f32[1,8,8,8] convolution(%x, %k), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
}
"""


def test_convolution_flops():
    tot = RL.analyze_hlo(CONV_HLO, 1)
    # 2 * out_elems * kernel_elems_per_output = 2 * (8*8*8) * (3*3*4)
    assert tot.flops == 2.0 * (8 * 8 * 8) * (3 * 3 * 4)


COLLECTIVE_HLO = """
ENTRY %main (x: f32[1024], y: f32[4096], z: f32[1024]) -> f32[256] {
  %x = f32[1024] parameter(0)
  %y = f32[4096] parameter(1)
  %z = f32[1024] parameter(2)
  %ar = f32[1024] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[4096] all-gather(%y), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %rs = f32[256] reduce-scatter(%z), replica_groups={{0,1,2,3}}, to_apply=%sum
}
"""


def test_collective_wire_bytes_classification():
    tot = RL.analyze_hlo(COLLECTIVE_HLO, 8)
    assert tot.coll_counts == {"all-reduce": 1, "all-gather": 1,
                               "reduce-scatter": 1}
    frac = 3.0 / 4.0                       # ring factor for group size 4
    want = (2 * 4096 * frac                # all-reduce: 2·size·frac
            + 16384 * frac                 # all-gather: size·frac
            + 1024 * 4 * frac)             # reduce-scatter: size·g·frac
    assert tot.wire_bytes == pytest.approx(want)


def test_group_size_fallback_to_n_devices():
    hlo = """
ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024] parameter(0)
  ROOT %ar = f32[1024] all-reduce(%x), to_apply=%sum
}
"""
    tot = RL.analyze_hlo(hlo, 8)
    assert tot.wire_bytes == pytest.approx(2 * 4096 * (7.0 / 8.0))


def test_hardware_spec_override():
    r = RL.Roofline(flops=1e12, bytes_accessed=1e9, collective_bytes=1e8,
                    collective_counts={}, n_devices=1)
    assert r.spec is TPU_V5E
    assert r.compute_s == pytest.approx(1e12 / TPU_V5E.peak_flops)
    slow = HardwareSpec(name="half", peak_flops=TPU_V5E.peak_flops / 2,
                        hbm_bw=TPU_V5E.hbm_bw / 2,
                        link_bw=TPU_V5E.link_bw / 2)
    r2 = r.with_spec(slow)
    assert r2.compute_s == pytest.approx(2 * r.compute_s)
    assert r2.memory_s == pytest.approx(2 * r.memory_s)
    assert r2.collective_s == pytest.approx(2 * r.collective_s)
    assert r2.to_dict()["hw_spec"] == "half"
    # module aliases stay wired to the default spec
    assert RL.PEAK_FLOPS == TPU_V5E.peak_flops
    assert RL.HBM_BW == TPU_V5E.hbm_bw
    assert RL.LINK_BW == TPU_V5E.link_bw


def test_latency_floor_enters_roofline_terms():
    r = RL.Roofline(flops=0.0, bytes_accessed=0.0, collective_bytes=0.0,
                    collective_counts={}, n_devices=1,
                    spec=HardwareSpec(name="floored", latency_floor=1e-3))
    assert r.compute_s == pytest.approx(1e-3)
    assert r.memory_s == pytest.approx(1e-3)


def test_analyze_compiled_smoke():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    def f(a, b):
        return a @ b

    a = jnp.ones((8, 16), jnp.float32)
    b = jnp.ones((16, 4), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    roof = RL.analyze(compiled, 1)
    assert roof.flops >= 1024.0            # at least the dot itself
    assert roof.bytes_accessed > 0
    custom = HardwareSpec(name="unit", peak_flops=1.0, hbm_bw=1.0, link_bw=1.0)
    assert RL.analyze(compiled, 1, spec=custom).compute_s == \
        pytest.approx(roof.flops)
