"""Observability plane (src/repro/obs): tracer span invariants, P² sketch
accuracy contract, registry scoping, export round-trips, the zero-overhead
(bit-identity) contract on engine and fleet runs, and the offline
critical-path/timeline analyzer. All seeded — part of the CI fast lane."""
import json

import numpy as np
import pytest

from repro.core.scenarios import MMPPArrivals, PoissonArrivals
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               P2Quantile)
from repro.obs.report import (critical_path, failure_timeline, load_trace,
                              render_report, request_paths)
from repro.obs.stats import latency_summary, percentile, throughput
from repro.obs.trace import Tracer, load_chrome, load_jsonl
from repro.runtime.controller import ClusterController
from repro.runtime.engine import (EngineConfig, ServingEngine,
                                  build_demo_server)
from repro.runtime.failures import FailureInjector, markov_flap_schedule
from repro.runtime.fleet import (FleetController, FleetEngine, FleetRouter,
                                 SLOClass, TenantSpec)
from tests.test_clock import _reports_identical
from tests.test_engine import _toy_ir


# -- stats: the one percentile convention -------------------------------------

def test_percentile_convention_and_edge_cases():
    xs = np.random.default_rng(0).exponential(size=257)
    # the repo-wide convention IS numpy linear interpolation
    assert percentile(xs, 99) == float(np.percentile(xs, 99))
    assert percentile([], 99) == float("inf")        # empty -> unservable
    assert percentile([0.25], 50) == 0.25            # single sample: itself
    assert percentile([0.25], 99) == 0.25


def test_throughput_and_latency_summary():
    assert throughput(0, 0.0, 1.0) == 0.0
    assert throughput(10, 0.0, 2.0) == 5.0
    assert throughput(1, 1.0, 1.0) > 0               # zero span guarded
    s = latency_summary([0.1, 0.2, 0.3, 0.4], slo=0.35)
    assert s["p50"] == pytest.approx(0.25)
    assert s["slo_attainment"] == pytest.approx(0.75)
    assert latency_summary([])["p99"] == float("inf")


# -- P² quantile sketch -------------------------------------------------------

def test_p2_exact_up_to_five_samples():
    sk = P2Quantile(0.5)
    assert np.isnan(sk.value())
    for xs in ([3.0], [3.0, 1.0], [3.0, 1.0, 2.0], [3.0, 1.0, 2.0, 9.0]):
        sk = P2Quantile(0.5)
        for x in xs:
            sk.observe(x)
        assert sk.value() == percentile(xs, 50)      # exact, same convention


def test_p2_rejects_degenerate_quantile():
    with pytest.raises(ValueError):
        P2Quantile(0.0)
    with pytest.raises(ValueError):
        P2Quantile(1.0)


@pytest.mark.parametrize("draw,p50_tol,p99_tol", [
    (lambda rng, n: rng.uniform(0.0, 1.0, n), 0.05, 0.15),
    (lambda rng, n: rng.exponential(1.0, n), 0.05, 0.15),
])
def test_p2_accuracy_contract(draw, p50_tol, p99_tol):
    """The documented bound: ≲5% on p50, ≲15% on p99 for smooth unimodal
    shapes at a few thousand samples."""
    rng = np.random.default_rng(42)
    xs = draw(rng, 4000)
    h = Histogram()
    for x in xs:
        h.observe(x)
    for q, tol in ((0.5, p50_tol), (0.99, p99_tol)):
        exact = percentile(xs, 100 * q)
        assert abs(h.quantile(q) - exact) / exact <= tol
    assert h.count == 4000
    assert h.min == xs.min() and h.max == xs.max()


def test_registry_scoping_and_type_guard():
    m = MetricsRegistry()
    m.counter("reqs", tenant="a").inc()
    m.counter("reqs", tenant="b").inc(2)
    assert m.counter("reqs", tenant="a").value == 1.0
    assert m.counter("reqs", tenant="b").value == 2.0
    m.gauge("depth").set(3.0)
    with pytest.raises(TypeError):
        m.histogram("reqs", tenant="a")              # name/type collision
    rows = m.collect()
    assert {r["type"] for r in rows} == {"counter", "gauge"}
    assert sorted(r["labels"].get("tenant", "") for r in rows
                  if r["name"] == "reqs") == ["a", "b"]
    assert isinstance(m.gauge("depth"), Gauge)
    assert isinstance(m.counter("reqs", tenant="a"), Counter)


# -- tracer unit invariants ---------------------------------------------------

def test_tracer_stack_discipline_enforced_at_record_time():
    tr = Tracer()
    outer = tr.begin("outer", "lane", t=0.0)
    inner = tr.begin("inner", "lane", t=0.1)
    with pytest.raises(RuntimeError, match="innermost"):
        tr.end(outer, t=0.2)                         # inner still open
    tr.end(inner, t=0.2)
    tr.end(outer, t=0.3)
    assert tr.open_spans() == []
    assert outer.contains(inner)
    assert not inner.contains(outer)


def test_tracer_seq_windows_certify_containment():
    tr = Tracer()
    sp = tr.begin("repair", "controller", t=0.0)
    bump = tr.instant("plan_epoch", "controller", t=0.0, epoch=1)
    tr.end(sp, t=0.1)
    outside = tr.instant("plan_epoch", "controller", t=0.05, epoch=2)
    assert sp.contains(bump)
    assert not sp.contains(outside)                  # time alone would lie


def test_chrome_and_jsonl_round_trips(tmp_path):
    tr = Tracer()
    a = tr.begin("request", "req/0", t=0.0, rid=0, bad=float("inf"))
    tr.instant("quorum_complete", "req/0", t=0.5, down={"b", "a"})
    tr.end(a, t=0.5)
    tr.complete("batch", "batches", 0.0, 0.5, bid=0)
    tr.begin("dangling", "batches", t=0.6)           # stays open on purpose
    for dump, load in ((tr.dump_chrome, load_chrome),
                       (tr.dump_jsonl, load_jsonl)):
        path = tmp_path / "t.trace.json"
        dump(str(path))
        back = load(str(path))
        assert [e.name for e in back] == [e.name for e in tr.events]
        assert all(abs(b.t - e.t) <= 1e-9
                   for b, e in zip(back, tr.events))
        by = {e.name: e for e in back}
        assert by["request"].attrs["bad"] == "inf"   # strict-JSON coercion
        assert by["quorum_complete"].attrs["down"] == ["a", "b"]
        assert by["dangling"].attrs.get("open") is True
        assert by["batch"].seq == by["batch"].end_seq
    # strict JSON throughout: no NaN/Infinity literals survive
    json.loads((tmp_path / "t.trace.json").read_text().splitlines()[0])


# -- instrumented runs: invariants + bit-identity -----------------------------

def _chaos_engine(tracer=None, metrics=None):
    ir = _toy_ir()
    srv = build_demo_server(ir, feat=8, hidden=16, n_classes=3, seed=0)
    events = markov_flap_schedule(list(ir.device_names), 0.2, 0.5, 60,
                                  np.random.default_rng(7))
    injector = FailureInjector(events)
    ctl = ClusterController(ir, server=srv, injector=injector, seed=0)
    cfg = EngineConfig(max_batch=8, max_wait=0.01, slo=0.2,
                       service_model=(2e-3, 1e-4), input_dim=8, seed=0,
                       chaos_every=0.02, pipeline_depth=2)
    return ServingEngine(srv, cfg, controller=ctl, tracer=tracer,
                         metrics=metrics)


def _chaos_trace():
    gen = MMPPArrivals(rates=(100.0, 1500.0), dwell=(0.05, 0.02),
                       sizes=(1, 2))
    return gen.generate(np.random.default_rng(3), 0.4)


def test_tracing_off_is_bit_identical_to_tracing_on():
    """The zero-overhead contract: attaching the obs plane changes no
    record, batch or migration — field for field."""
    times, sizes = _chaos_trace()
    plain = _chaos_engine().run(times, sizes)
    traced = _chaos_engine(tracer=Tracer(),
                           metrics=MetricsRegistry()).run(times, sizes)
    _reports_identical(plain, traced)


def test_chaos_run_span_invariants():
    tr, m = Tracer(), MetricsRegistry()
    times, sizes = _chaos_trace()
    eng = _chaos_engine(tracer=tr, metrics=m)
    rep = eng.run(times, sizes)

    # every admitted request: exactly one CLOSED root span, matching times
    assert tr.open_spans() == []
    done = [r for r in rep.records if np.isfinite(r.t_done)]
    roots = tr.spans("request")
    assert len(roots) == len(done) == len(rep.records)
    by_rid = {int(s.attrs["rid"]): s for s in roots}
    for r in done:
        s = by_rid[r.rid]
        assert s.t == r.t_arrival and s.t_end == pytest.approx(r.t_done)
        assert s.attrs["outcome"] in ("quorum_complete", "degraded")

    # batch_wait + service sum to the measured latency, per request
    for p in request_paths(tr.events):
        segs = dict(p.segments)
        assert set(segs) <= {"batch_wait", "service", "share_wait",
                             "merge_tail"}
        assert sum(segs.values()) == pytest.approx(p.latency, abs=1e-9)

    # per-track discipline holds globally: spans on one stack-disciplined
    # track nest or are disjoint — they never partially overlap. Batch
    # spans are exempt by design: under pipeline_depth > 1 consecutive
    # micro-batches legitimately run concurrently on the batches track,
    # bounded by the configured depth.
    by_track = {}
    for e in tr.events:
        if e.phase == "X":
            by_track.setdefault(e.track, []).append(e)
    for track, spans in by_track.items():
        if track.endswith("batches"):
            depth = eng.cfg.pipeline_depth
            for s in spans:
                live = sum(1 for o in spans
                           if o.t < s.t_end - 1e-12 and s.t < o.t_end - 1e-12)
                assert live <= depth
            continue
        spans = sorted(spans, key=lambda s: (s.t, -s.t_end))
        for a, b in zip(spans, spans[1:]):
            assert b.t >= a.t_end - 1e-12 or \
                (a.t <= b.t and b.t_end <= a.t_end + 1e-12)

    # controller repair spans bracket their plan-epoch bump (seq windows)
    repairs = [s for s in tr.spans(track="controller")
               if s.name in ("repair", "full_replan", "reencode", "noop")]
    bumps = tr.instants("plan_epoch", "controller")
    assert len(repairs) == len(rep.migrations) == len(bumps) > 0
    for sp, bump in zip(repairs, bumps):
        assert sp.contains(bump)
        assert sp.attrs["epoch"] == bump.attrs["epoch"]

    # chaos instants + serve_batch wall spans + migrate instants landed
    assert len(tr.instants("chaos_tick", "chaos")) > 0
    assert len(tr.spans("serve_batch", "server")) == len(rep.batches)
    assert len(tr.instants("migrate", "server")) == len(rep.migrations)

    # metrics agree with the report within the documented sketch error
    s = rep.summary()
    assert m.counter("requests_served").value == s["n"]
    sketch = m.histogram("request_latency_s").quantile(0.99)
    assert abs(sketch - s["p99"]) / s["p99"] <= 0.15


def test_shed_requests_get_terminal_shed_span():
    """A same-instant burst behind pipeline_depth=1: the overflow is shed
    by admission control and must close with a zero-duration terminal
    ``shed`` span (still exactly one closed root per request)."""
    ir = _toy_ir()
    srv = build_demo_server(ir, feat=8, hidden=16, n_classes=3, seed=0)
    pred = float(ir.objective())
    cfg = EngineConfig(max_batch=8, max_wait=0.01, slo=pred + 1e-3,
                       service_model=(2e-3, 1e-4), input_dim=8, seed=0,
                       pipeline_depth=1, admission=True)
    tr = Tracer()
    eng = ServingEngine(srv, cfg, tracer=tr, metrics=MetricsRegistry())
    rep = eng.run(np.zeros(20), np.ones(20, np.int64))
    shed = [r for r in rep.records if r.rejected]
    assert len(shed) > 0 and len(shed) < 20
    assert tr.open_spans() == []
    assert len(tr.spans("request")) == 20            # one root each, closed
    terms = tr.spans("shed")
    assert len(terms) == len(shed)
    assert all(t.dur == 0.0 for t in terms)
    for r in shed:
        root = next(s for s in tr.spans("request")
                    if s.attrs["rid"] == r.rid)
        assert root.attrs["outcome"] == "shed"
    assert eng.metrics.counter("requests_shed").value == len(shed)
    # shed requests are excluded from critical paths unless asked for
    assert all(p.outcome != "shed" for p in request_paths(tr.events))
    got = request_paths(tr.events, include_shed=True)
    assert sum(1 for p in got if p.outcome == "shed") == len(shed)


# -- fleet: tracer threaded through lanes, router, broker ---------------------

def _tenant(name, ir, slo_cls, seed=0):
    srv = build_demo_server(ir, feat=8, hidden=16, n_classes=3, seed=0)
    ctl = ClusterController(ir, server=srv, seed=0, require_feasible=False)
    cfg = EngineConfig(max_batch=8, max_wait=0.01, slo=slo_cls.slo,
                       service_model=(2e-3, 1e-4), input_dim=8, seed=0,
                       pipeline_depth=2)
    return TenantSpec(name, srv, controller=ctl, slo=slo_cls, config=cfg)


def test_fleet_traced_run_and_bit_identity():
    from tests.test_fleet import _tenant_ir

    def build(tracer=None, metrics=None):
        tenants = [
            _tenant("gold", _tenant_ir("g"), SLOClass("gold", 0.2, 4.0)),
            _tenant("bulk", _tenant_ir("b"), SLOClass("bronze", 0.2, 1.0)),
        ]
        injector = FailureInjector(markov_flap_schedule(
            [d for t in ("g", "b") for d in
             (f"{t}-a", f"{t}-b", f"{t}-c", f"{t}-d")],
            0.2, 0.5, 30, np.random.default_rng(7)))
        fc = FleetController(tenants, [])
        return FleetEngine(tenants, router=FleetRouter("predicted"),
                           fleet_controller=fc, injector=injector,
                           chaos_every=0.02, seed=0,
                           tracer=tracer, metrics=metrics)

    traces = [PoissonArrivals(300.0).generate(np.random.default_rng(s), 0.3)
              for s in (2, 5)]
    plain = build().run([(t, s) for t, s in traces])
    tr, m = Tracer(), MetricsRegistry()
    traced = build(tracer=tr, metrics=m).run([(t, s) for t, s in traces])

    # bit-identity per tenant
    for a, b in zip(plain.reports, traced.reports):
        _reports_identical(a, b)

    # lane spans carry the tenant prefix; fleet tracks carry fleet events
    assert tr.open_spans() == []
    assert len(tr.spans("request")) == sum(len(r.records)
                                           for r in traced.reports)
    tenants_seen = {p.tenant for p in request_paths(tr.events)}
    assert tenants_seen == {"gold", "bulk"}
    routes = tr.instants("route", "fleet/router")
    assert len(routes) == sum(len(r.batches) for r in traced.reports)
    assert all(r.attrs["policy"] == "predicted" for r in routes)
    assert {r.attrs["picked"] for r in routes} == {"gold", "bulk"}
    assert len(tr.instants("chaos_tick", "fleet/chaos")) > 0
    # repairs landed on per-tenant controller tracks
    n_rep = sum(len(r.migrations) for r in traced.reports)
    assert sum(len(tr.spans(track=f"{t}/controller"))
               for t in ("gold", "bulk")) == n_rep
    # metrics scoped per tenant + slo class
    assert m.counter("requests_served", tenant="gold",
                     slo_class="gold").value > 0
    assert m.counter("requests_served", tenant="bulk",
                     slo_class="bronze").value > 0


def test_fleet_spare_claims_traced():
    """The cross-tenant contention scenario with the tracer attached: the
    broker's exclusive claim shows up as a ``spare_claim`` instant on the
    fleet/spares track, attributed to the winning tenant."""
    from tests.test_fleet import _cfg as fleet_cfg
    from tests.test_fleet import _spare, _tenant_ir
    from repro.runtime.failures import FailureEvent
    spare = _spare("spare-0")
    ir_a = _tenant_ir("ta", [spare], p_out=0.7)
    ir_b = _tenant_ir("tb", [spare, _spare("tb-priv")], p_out=0.7)
    srv_a = build_demo_server(ir_a, feat=8, hidden=16, n_classes=3, seed=0)
    srv_b = build_demo_server(ir_b, feat=8, hidden=16, n_classes=3, seed=0)
    ctl_a = ClusterController(ir_a, server=srv_a, seed=0)
    ctl_b = ClusterController(ir_b, server=srv_b, seed=0,
                              require_feasible=False)
    tenants = [TenantSpec("ta", srv_a, controller=ctl_a,
                          slo=SLOClass("gold", slo=0.2, weight=4.0),
                          config=fleet_cfg(admission=False)),
               TenantSpec("tb", srv_b, controller=ctl_b,
                          slo=SLOClass("bronze", slo=0.2, weight=1.0),
                          config=fleet_cfg(admission=False))]
    fc = FleetController(tenants, ["spare-0"])
    injector = FailureInjector([
        FailureEvent(0, d) for d in ("ta-a", "ta-b", "tb-a", "tb-b")])
    tr = Tracer()
    fleet = FleetEngine(tenants, fleet_controller=fc, injector=injector,
                        chaos_every=0.02, seed=0, tracer=tr)
    fleet.run([(np.arange(0.03, 0.3, 0.005), None),
               (np.arange(0.032, 0.3, 0.005), None)])
    claims = tr.instants("spare_claim", "fleet/spares")
    assert any(c.attrs["device"] == "spare-0" and c.attrs["tenant"] == "ta"
               for c in claims)
    # and the timeline analyzer surfaces the whole story in order
    rows = failure_timeline(tr.events)
    whats = [w for _, _, w, _ in rows]
    assert "chaos_tick" in whats and "failure_observed" in whats
    assert "spare_claim" in whats
    assert any(w in ("repair", "full_replan") for w in whats)
    ts = [t for t, _, _, _ in rows]
    assert ts == sorted(ts)


# -- offline analyzer ---------------------------------------------------------

def test_critical_path_segments_sum_to_measured_latency(tmp_path):
    tr = Tracer()
    times, sizes = _chaos_trace()
    rep = _chaos_engine(tracer=tr).run(times, sizes)
    s = rep.summary()
    cp = critical_path(tr.events, q=99.0)
    assert cp.n == s["n"]
    assert cp.target_latency == pytest.approx(s["p99"])  # same convention
    seg_sum = sum(d for _, d in cp.path.segments)
    assert seg_sum == pytest.approx(cp.path.latency, abs=1e-9)
    # the picked request is a real one with a real latency
    real = next(r for r in rep.records if r.rid == cp.path.rid)
    assert cp.path.latency == pytest.approx(real.latency)

    # render + round-trip the whole report through both file formats
    text = render_report(tr.events, q=99.0, timeline_limit=5)
    assert "critical path" in text and "timeline" in text
    for dump, name in ((tr.dump_chrome, "t.trace.json"),
                       (tr.dump_jsonl, "t.jsonl")):
        path = tmp_path / name
        dump(str(path))
        back = load_trace(str(path))
        cp2 = critical_path(back, q=99.0)
        assert cp2.path.rid == cp.path.rid
        assert cp2.path.latency == pytest.approx(cp.path.latency)


def test_trace_report_cli(tmp_path, capsys):
    import scripts.trace_report as cli
    tr = Tracer()
    times, sizes = _chaos_trace()
    _chaos_engine(tracer=tr).run(times, sizes)
    path = tmp_path / "run.trace.json"
    tr.dump_chrome(str(path))
    assert cli.main([str(path), "-q", "50", "--timeline-limit", "3"]) == 0
    out = capsys.readouterr().out
    assert "p50 critical path" in out
    assert "failure/repair timeline" in out


def test_engine_report_percentiles_route_through_stats():
    """The dedupe satellite: EngineReport.summary's p50/p99 are exactly
    the shared convention (no drift between report and analyzer)."""
    times, sizes = _chaos_trace()
    rep = _chaos_engine().run(times, sizes)
    s = rep.summary()
    lats = [r.latency for r in rep.records if np.isfinite(r.t_done)]
    assert s["p99"] == percentile(lats, 99)
    assert s["p50"] == percentile(lats, 50)


def test_tracer_state_does_not_leak_across_runs():
    """Per-run request-span bookkeeping is reset: a second run on the same
    engine appends a full second trace and still closes every span (the
    controller's plan state legitimately carries over, so the second run's
    event count may differ)."""
    tr = Tracer()
    times, sizes = _chaos_trace()
    eng = _chaos_engine(tracer=tr)
    rep1 = eng.run(times, sizes)
    n1 = len(tr.events)
    n_roots1 = len(tr.spans("request"))
    assert n_roots1 == len(rep1.records)
    rep2 = eng.run(times, sizes)
    assert tr.open_spans() == []
    assert len(tr.events) > n1
    assert len(tr.spans("request")) == n_roots1 + len(rep2.records)
