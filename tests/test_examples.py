"""Fast-lane smoke tests: every ``examples/*.py`` main() runs end-to-end.

Each example is loaded from its file path (``examples/`` is not a package)
and its heavy knobs — training steps, Monte-Carlo trials, arrival horizons —
are shrunk by monkeypatching the module's imported symbols, so the full
control flow (train → plan → serve → repair) executes in seconds.
"""
import functools
import importlib.util
import pathlib
import sys

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"


def _load(name: str):
    """Import ``examples/<name>.py`` as a throwaway module."""
    spec = importlib.util.spec_from_file_location(
        f"_example_{name}", EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def _shrunk(fn, **overrides):
    """Wrap ``fn`` forcing the given keyword arguments."""
    @functools.wraps(fn)
    def wrapped(*args, **kw):
        return fn(*args, **{**kw, **overrides})
    return wrapped


def test_quickstart(monkeypatch):
    mod = _load("quickstart")
    monkeypatch.setattr(mod, "train_run",
                        _shrunk(mod.train_run, steps=4, batch=2, seq=32))
    monkeypatch.setattr(mod, "generate",
                        _shrunk(mod.generate, prompt_len=8, gen=4, batch=1))
    monkeypatch.setattr(mod.SIM, "simulate",
                        _shrunk(mod.SIM.simulate, trials=50))
    mod.main()


def test_train_lm(monkeypatch):
    mod = _load("train_lm")
    monkeypatch.setattr(mod, "run",
                        _shrunk(mod.run, steps=12, batch=2, seq=32,
                                ckpt_every=4, log_every=4))
    mod.main()


def test_distill_and_serve(monkeypatch):
    mod = _load("distill_and_serve")
    monkeypatch.setattr(
        mod, "build_rocoin",
        _shrunk(mod.build_rocoin, teacher_steps=3, student_steps=2,
                batch=16, zoo=["wrn-10-1"]))
    mod.main()


def test_fault_tolerant_serving(monkeypatch):
    mod = _load("fault_tolerant_serving")
    monkeypatch.setattr(
        mod, "build_rocoin",
        _shrunk(mod.build_rocoin, teacher_steps=3, student_steps=2,
                batch=16))
    monkeypatch.setattr(mod, "simulate", _shrunk(mod.simulate, trials=500))
    mod.main()


def test_coded_serving(monkeypatch):
    mod = _load("coded_serving")
    monkeypatch.setattr(mod, "simulate", _shrunk(mod.simulate, trials=200))
    mod.main()


def test_streaming_serving(monkeypatch):
    mod = _load("streaming_serving")

    def short_horizon(cls):
        class _Short(cls):
            def generate(self, rng, horizon, *a, **kw):
                return super().generate(rng, min(horizon, 0.08), *a, **kw)
        return _Short

    monkeypatch.setattr(mod, "PoissonArrivals",
                        short_horizon(mod.PoissonArrivals))
    monkeypatch.setattr(mod, "MMPPArrivals",
                        short_horizon(mod.MMPPArrivals))
    mod.main()


def test_traced_serving(monkeypatch, capsys):
    mod = _load("traced_serving")

    def short_horizon(cls):
        class _Short(cls):
            def generate(self, rng, horizon, *a, **kw):
                return super().generate(rng, min(horizon, 0.08), *a, **kw)
        return _Short

    monkeypatch.setattr(mod, "MMPPArrivals",
                        short_horizon(mod.MMPPArrivals))
    mod.main()
    out = capsys.readouterr().out
    assert "critical path" in out
    assert "failure/repair timeline" in out
    assert "0 left open" in out
