"""Virtual-clock scheduler primitives (runtime/clock.py).

The engine's event loop was extracted into EventQueue/CloseTimer so the
multi-tenant fleet router shares one scheduler implementation. These tests
pin (a) the primitives' semantics and (b) fixed-seed BIT-identity of the
refactored ServingEngine against a frozen copy of the pre-refactor raw
-heapq loop. Part of the CI fast lane."""
import dataclasses
import heapq
from collections import deque

import numpy as np

from repro.core.scenarios import MMPPArrivals, PoissonArrivals
from repro.runtime.clock import EPS, CloseTimer, EventQueue, periodic_ticks
from repro.runtime.controller import ClusterController
from repro.runtime.engine import (EngineConfig, EngineReport, ServingEngine,
                                  build_demo_server)
from repro.runtime.failures import FailureInjector, markov_flap_schedule


# -- primitives ---------------------------------------------------------------

def test_event_queue_orders_by_time_then_push_order():
    q = EventQueue()
    q.push(2.0, 0, "late")
    q.push(1.0, 0, "a")
    q.push(1.0, 1, "b")          # same time: push order must win
    q.push(0.5, 9, "first")
    out = [q.pop() for _ in range(len(q))]
    assert [p for _, _, p in out] == ["first", "a", "b", "late"]
    assert not q


def test_event_queue_matches_reference_heapq_on_random_program():
    """Any push program pops identically to the raw (t, seq, kind, payload)
    tuple heap the engine used before the extraction."""
    rng = np.random.default_rng(0)
    q = EventQueue()
    heap, seq = [], 0
    for i in range(500):
        t = float(rng.choice([0.1, 0.5, 0.5, 1.0, rng.random()]))
        kind = int(rng.integers(0, 5))
        q.push(t, kind, i)
        heapq.heappush(heap, (t, seq, kind, i))
        seq += 1
    while heap:
        t, _, kind, payload = heapq.heappop(heap)
        assert q.pop() == (t, kind, payload)
    assert not q


def test_close_timer_arm_once_semantics():
    q = EventQueue()
    timer = CloseTimer(q, kind=1)
    timer.arm(1.0, now=0.0)
    timer.arm(1.0, now=0.0)      # same deadline: no second event
    timer.arm(2.0, now=0.0)      # later deadline: ignored
    assert len(q) == 1
    timer.arm(0.5, now=0.0)      # strictly earlier: re-armed
    assert len(q) == 2 and timer.armed_at == 0.5
    t, _, _ = q.pop()            # stale 1.0 event pops later; 0.5 first
    timer.fired(t)
    assert timer.armed_at == float("inf")
    t, _, _ = q.pop()            # the superseded 1.0 event: a stale pop
    timer.fired(t)               # must not raise, timer stays unarmed
    assert timer.armed_at == float("inf")
    timer.arm(3.0, now=2.5)      # fresh window after firing
    assert timer.armed_at == 3.0


def test_periodic_ticks_by_index_not_accumulation():
    every = 0.1
    t_end = 0.7000000000000001       # accumulation would drop tick 7
    ticks = periodic_ticks(every, t_end)
    assert len(ticks) == 7
    assert np.allclose(ticks, every * np.arange(1, 8))
    assert periodic_ticks(0.0, 1.0).size == 0
    assert periodic_ticks(0.1, 0.0).size == 0


# -- frozen pre-refactor engine loop ------------------------------------------

class _LegacyLoopEngine(ServingEngine):
    """ServingEngine with the PR-7 raw-heapq ``_run`` body, frozen verbatim
    (modulo the extracted-state names) — the bit-identity oracle for the
    clock.py refactor."""

    def _run(self, times, sizes) -> EngineReport:
        from repro.runtime.engine import RequestRecord, BatchRecord  # noqa: F401
        times = np.asarray(times, np.float64)
        if sizes is None:
            sizes = np.ones(len(times), np.int64)
        sizes = np.asarray(sizes, np.int64)
        from repro.runtime.engine import RequestRecord
        records = [RequestRecord(i, float(times[i]), int(sizes[i]))
                   for i in range(len(times))]
        if self.cfg.warmup and self.cfg.service_model is None and records:
            self._warmup(sizes)

        heap, seq = [], 0
        ARRIVE, CLOSE, DONE, CHAOS, SHARE = 0, 1, 2, 3, 4
        for r in records:
            heapq.heappush(heap, (r.t_arrival, seq, ARRIVE, r.rid))
            seq += 1
        if self.injector is not None and self.cfg.chaos_every:
            t_end = float(times.max()) if len(times) else 0.0
            n_ticks = int(np.floor(t_end / self.cfg.chaos_every + 1e-9))
            for i in range(1, n_ticks + 1):
                heapq.heappush(heap, (i * self.cfg.chaos_every, seq,
                                      CHAOS, -1))
                seq += 1

        queue = deque()
        in_flight = 0
        bid = 0
        timer_at = float("inf")
        batches = []

        def due(now):
            return bool(queue) and (
                len(queue) >= self.cfg.max_batch
                or now >= records[queue[0]].t_arrival
                + self.cfg.max_wait - 1e-12)

        def admit(now):
            if not self.cfg.admission or not queue:
                return
            pred = self.server.ir.objective()
            survivors = [rid for rid in queue
                         if now - records[rid].t_arrival + pred
                         <= self.cfg.slo + 1e-12]
            if len(survivors) != len(queue):
                for rid in queue:
                    if now - records[rid].t_arrival + pred \
                            > self.cfg.slo + 1e-12:
                        records[rid].rejected = True
                queue.clear()
                queue.extend(survivors)

        def try_dispatch(now):
            nonlocal in_flight, bid, seq, timer_at
            admit(now)
            while queue and in_flight < self.cfg.pipeline_depth and due(now):
                take = [records[queue.popleft()]
                        for _ in range(min(len(queue), self.cfg.max_batch))]
                done_t, batch, share_events = self._dispatch(now, take, bid)
                batches.append(batch)
                heapq.heappush(heap, (done_t, seq, DONE, bid))
                seq += 1
                for t_sh, fut_idx in share_events:
                    heapq.heappush(heap, (t_sh, seq, SHARE, fut_idx))
                    seq += 1
                bid += 1
                in_flight += 1
            if queue and not due(now):
                close_at = records[queue[0]].t_arrival + self.cfg.max_wait
                if close_at < timer_at - 1e-12 or timer_at <= now:
                    timer_at = close_at
                    heapq.heappush(heap, (close_at, seq, CLOSE, -1))
                    seq += 1

        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == ARRIVE:
                queue.append(payload)
                try_dispatch(now)
            elif kind == CLOSE:
                if timer_at <= now + 1e-12:
                    timer_at = float("inf")
                try_dispatch(now)
            elif kind == DONE:
                in_flight -= 1
                try_dispatch(now)
            elif kind == SHARE:
                fut = self.futures[payload]
                if fut.arrived < fut.k:
                    fut.arrived += 1
                    if fut.arrived == fut.k:
                        fut.t_complete = now
                else:
                    fut.cancelled += 1
            else:
                down = set(self.injector.tick())
                if self.controller is not None:
                    self.controller.observe_deferred(down)
                else:
                    self._down = down
        return EngineReport(records, batches, self.migrations,
                            self.cfg.slo, self.futures)


def _reports_identical(a: EngineReport, b: EngineReport) -> None:
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert dataclasses.astuple(ra) == dataclasses.astuple(rb)
    assert [dataclasses.astuple(x) for x in a.batches] \
        == [dataclasses.astuple(x) for x in b.batches]
    assert len(a.migrations) == len(b.migrations)
    for (ta, oa), (tb, ob) in zip(a.migrations, b.migrations):
        assert ta == tb and oa.kind == ob.kind \
            and oa.moved_devices == ob.moved_devices


def _engines(engine_cls, *, chaos: bool, seed: int):
    from tests.test_engine import _toy_ir
    ir = _toy_ir()
    srv = build_demo_server(ir, feat=8, hidden=16, n_classes=3, seed=0)
    cfg = EngineConfig(max_batch=8, max_wait=0.01, slo=0.2,
                       service_model=(2e-3, 1e-4), input_dim=8, seed=seed,
                       chaos_every=0.02 if chaos else None,
                       pipeline_depth=2, admission=True)
    ctl = injector = None
    if chaos:
        events = markov_flap_schedule(list(ir.device_names), 0.2, 0.5, 60,
                                      np.random.default_rng(7))
        injector = FailureInjector(events)
        ctl = ClusterController(ir, server=srv, injector=injector, seed=0)
    return engine_cls(srv, cfg, controller=ctl, injector=injector)


def test_engine_bit_identical_to_frozen_prerefactor_loop():
    """The clock.py port of ServingEngine._run reproduces the PR-7 raw
    -heapq loop record for record — Poisson and bursty MMPP traces, with
    and without live chaos/migration."""
    for chaos in (False, True):
        for gen, gseed in ((PoissonArrivals(400.0, (1, 2, 4),
                                            (0.5, 0.3, 0.2)), 2),
                           (MMPPArrivals(rates=(100.0, 1500.0),
                                         dwell=(0.05, 0.02),
                                         sizes=(1, 2)), 3)):
            times, sizes = gen.generate(np.random.default_rng(gseed), 0.4)
            new = _engines(ServingEngine, chaos=chaos, seed=0)
            old = _engines(_LegacyLoopEngine, chaos=chaos, seed=0)
            _reports_identical(new.run(times, sizes), old.run(times, sizes))


def test_eps_matches_legacy_slack():
    assert EPS == 1e-12
