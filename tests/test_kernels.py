"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 else \
           dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,KV,G,S,D", [(1, 1, 1, 128, 64), (2, 2, 4, 256, 64),
                                        (1, 4, 2, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, KV, G, S, D, dtype, causal):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, KV, G, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,KV,G,S,D", [(2, 2, 4, 256, 64), (1, 1, 8, 512, 128)])
@pytest.mark.parametrize("length", [1, 100, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, KV, G, S, D, length, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, D), dtype)
    kc = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    vc = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.int32(length), block_kv=128)
    exp = ref.decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("BH,L,P,N,chunk", [(2, 64, 16, 16, 16),
                                            (3, 128, 32, 64, 32),
                                            (1, 256, 64, 128, 64)])
def test_ssd_scan(BH, L, P, N, chunk):
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (BH, L, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, L)))
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)))
    Bm = jax.random.normal(ks[3], (BH, L, N))
    Cm = jax.random.normal(ks[4], (BH, L, N))
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    exp = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-3, rtol=2e-3)


def test_ssd_scan_matches_model_chunked():
    """Kernel must agree with the model's ssd_chunked (the lowered path)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.key(5), 5)
    B, L, H, P, N = 2, 64, 3, 16, 32
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, 16)
    # kernel layout: (B*H, L, ·) with per-head A and per-head dt
    xk = x.transpose(0, 2, 1, 3).reshape(B * H, L, P)
    dtk = dt.transpose(0, 2, 1).reshape(B * H, L)
    Ak = jnp.tile(A, B)
    Bk = jnp.repeat(Bm, H, axis=0)
    Ck = jnp.repeat(Cm, H, axis=0)
    y_k = ops.ssd_scan(xk, dtk, Ak, Bk, Ck, chunk=16)
    y_k = y_k.reshape(B, H, L, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_model, np.float32),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("shape", [(4, 64, 256), (1, 7, 512), (2, 100, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(jax.random.key(3), 2)
    x = jax.random.normal(ks[0], shape, dtype)
    sc = jax.random.normal(ks[1], (shape[-1],), dtype)
    out = ops.rmsnorm(x, sc, block_rows=32)
    exp = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("K,B,Dk,C", [(4, 64, 32, 10), (8, 100, 16, 100),
                                      (1, 32, 64, 10)])
def test_quorum_aggregate(K, B, Dk, C):
    ks = jax.random.split(jax.random.key(4), 4)
    p = jax.random.normal(ks[0], (K, B, Dk))
    w = jax.random.normal(ks[1], (K, Dk, C))
    b = jax.random.normal(ks[2], (C,))
    mask = (jax.random.uniform(ks[3], (K,)) > 0.3).astype(jnp.int32)
    out = ops.quorum_aggregate(p, w, b, mask, block_batch=32)
    exp = ref.quorum_aggregate_ref(p, w, b, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_quorum_all_failed_is_bias():
    p = jnp.ones((3, 8, 4))
    w = jnp.ones((3, 4, 5))
    b = jnp.arange(5.0)
    out = ops.quorum_aggregate(p, w, b, jnp.zeros(3, jnp.int32))
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(np.arange(5.0), (8, 5)))


@pytest.mark.parametrize("B,R,K,F", [(5, 6, 4, 16), (128, 3, 2, 8),
                                     (1, 9, 7, 32)])
def test_coded_decode(B, R, K, F):
    ks = jax.random.split(jax.random.key(7), 3)
    sh = jax.random.normal(ks[0], (B, R, F))
    dec = jax.random.normal(ks[1], (B, K, R))
    mask = (jax.random.uniform(ks[2], (B, R)) > 0.3).astype(jnp.int32)
    out = ops.coded_decode(sh, dec, mask, block_batch=32)
    exp = ref.coded_decode_ref(sh, dec, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,R,K,F", [(16, 5, 3, 16), (64, 4, 4, 8)])
def test_coded_decode_int8_shares(B, R, K, F):
    ks = jax.random.split(jax.random.key(8), 3)
    sh = jax.random.randint(ks[0], (B, R, F), -127, 128, jnp.int8)
    dec = jax.random.normal(ks[1], (B, K, R))
    mask = (jax.random.uniform(ks[2], (B, R)) > 0.4).astype(jnp.int32)
    scales = jnp.abs(jax.random.normal(jax.random.key(9), (R,))) + 0.1
    out = ops.coded_decode(sh, dec, mask, scales, block_batch=32)
    exp = ref.coded_decode_ref(sh, dec, mask, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_coded_decode_int8_needs_scales():
    with pytest.raises(ValueError, match="scales"):
        ops.coded_decode(jnp.zeros((2, 3, 4), jnp.int8),
                         jnp.zeros((2, 2, 3)), jnp.ones((2, 3), jnp.int32))


def test_coded_decode_dead_shares_contribute_nothing():
    """An all-dead mask yields exactly zero regardless of share payloads."""
    sh = jnp.ones((4, 3, 8)) * 1e6
    dec = jnp.ones((4, 2, 3))
    out = ops.coded_decode(sh, dec, jnp.zeros((4, 3), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 2, 8)))


@pytest.mark.parametrize("N,E,k", [(128, 8, 2), (1000, 64, 6), (77, 16, 4)])
def test_topk_gating(N, E, k):
    lg = jax.random.normal(jax.random.key(6), (N, E))
    w1, i1 = ops.topk_gating(lg, k, block_rows=64)
    w2, i2 = ref.topk_gating_ref(lg, k)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               atol=1e-5, rtol=1e-5)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    # weights renormalized
    np.testing.assert_allclose(np.asarray(w1).sum(-1), 1.0, atol=1e-5)
