"""Measured device-spec tests: fit recovery, declared equivalence, and the
measured-mode PlanIR consumed end-to-end (planner → select_redundancy →
engine admission)."""
import numpy as np
import pytest

from repro.core import planner as PL
from repro.core.assignment import StudentArch
from repro.core.grouping import Device
from repro.core.hwspec import (DeviceSpec, HardwareSpec, declared_specs,
                               fit_device_spec, measured_latency_matrix,
                               scaled_fleet_specs)
from repro.core.plan_ir import PlanIR, eq1a_latency


def _fleet(n=6):
    return [Device(f"d{i}", 2.0 + i, 8.0, 1.0 + 0.5 * i, 0.05)
            for i in range(n)]


def _students(s=4):
    return [StudentArch(f"s{i}", 1.0 + i, 2.0 + i, 0.5, 1.0 + i)
            for i in range(s)]


def _graph(M=12, seed=0):
    rng = np.random.default_rng(seed)
    A = np.abs(rng.standard_normal((M, M)))
    A = 0.5 * (A + A.T)
    np.fill_diagonal(A, 0)
    return A


# ---------------------------------------------------------------------------
# the fit
# ---------------------------------------------------------------------------

def test_fit_recovers_synthetic_spec():
    rng = np.random.default_rng(0)
    true = DeviceSpec("true", peak_flops=2e9, peak_bw=5e8, latency_floor=2e-4)
    flops = rng.uniform(1e6, 1e9, 40)
    nbytes = rng.uniform(1e4, 1e7, 40)
    wall = true.latency(flops, nbytes)
    fit = fit_device_spec(flops, nbytes, wall)
    assert fit.peak_flops == pytest.approx(true.peak_flops, rel=1e-6)
    assert fit.peak_bw == pytest.approx(true.peak_bw, rel=1e-6)
    assert fit.latency_floor == pytest.approx(true.latency_floor, rel=1e-6)


def test_fit_drops_unbound_terms():
    # wall time independent of flops: the compute coefficient must go to
    # zero, which surfaces as an effectively-infinite peak, never a
    # negative rate — and the fit still predicts the samples
    flops = np.array([1e6, 2e6, 3e6, 4e6])
    nbytes = np.array([1e4, 1e4, 1e4, 1e4])
    wall = np.full(4, 1e-3)
    fit = fit_device_spec(flops, nbytes, wall)
    assert fit.peak_flops >= 1e29
    assert fit.latency_floor >= 0.0 and fit.peak_bw > 0.0
    assert float(fit.latency(1e6, 1e4)) == pytest.approx(1e-3, rel=1e-3)


def test_fit_rejects_mismatched_samples():
    with pytest.raises(ValueError):
        fit_device_spec(np.ones(3), np.ones(2), np.ones(3))


def test_scaled_fleet_keeps_declared_ratios():
    host = DeviceSpec("host", peak_flops=1e9, peak_bw=1e8,
                      latency_floor=1e-4)
    devs = _fleet(4)
    specs = scaled_fleet_specs(host, devs)
    ref_core = max(d.c_core for d in devs)
    for d, s in zip(devs, specs):
        assert s.name == d.name
        assert s.peak_flops == pytest.approx(1e9 * d.c_core / ref_core)
        assert s.latency_floor == host.latency_floor
    # the fastest declared device gets exactly the host's measured scale
    assert max(s.peak_flops for s in specs) == pytest.approx(1e9)


def test_device_spec_round_trip():
    s = DeviceSpec("x", 1.5e9, 2.5e8, 3e-4, source="measured")
    assert DeviceSpec.from_dict(s.to_dict()) == s


def test_hardware_spec_with():
    assert HardwareSpec().with_(peak_flops=1.0).peak_flops == 1.0


# ---------------------------------------------------------------------------
# declared equivalence + measured PlanIR
# ---------------------------------------------------------------------------

def test_declared_specs_reproduce_eq1a_exactly():
    devs, studs = _fleet(), _students()
    from repro.core.plan_ir import device_matrix, student_matrix
    _, dcaps = device_matrix(devs)
    _, scaps = student_matrix(studs)
    declared = eq1a_latency(scaps, dcaps)
    measured = measured_latency_matrix(declared_specs(devs), scaps)
    np.testing.assert_array_equal(declared, measured)


def test_eq1a_latency_spec_count_mismatch():
    devs, studs = _fleet(3), _students(2)
    from repro.core.plan_ir import device_matrix, student_matrix
    _, dcaps = device_matrix(devs)
    _, scaps = student_matrix(studs)
    with pytest.raises(ValueError):
        eq1a_latency(scaps, dcaps, declared_specs(devs[:2]))


def test_fixed_seed_plan_equivalence_measured_vs_declared():
    """The acceptance pin: measured specs equal to the declared capacities
    must plan identically (same groups, partitions, students, latency)."""
    devs, studs, A = _fleet(), _students(), _graph()
    ir_d = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0)
    ir_m = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0,
                           device_specs=declared_specs(devs))
    assert ir_d is not None and ir_m is not None
    np.testing.assert_array_equal(ir_d.member, ir_m.member)
    np.testing.assert_array_equal(ir_d.partition, ir_m.partition)
    np.testing.assert_array_equal(ir_d.student_of, ir_m.student_of)
    np.testing.assert_array_equal(ir_d.latency_nd, ir_m.latency_nd)
    assert ir_d.latency_source == "declared"
    assert ir_m.latency_source == "measured"
    assert ir_m.objective() == ir_d.objective()
    ir_m.validate()


def test_slower_measured_specs_change_the_latency():
    devs, studs, A = _fleet(), _students(), _graph()
    slow = tuple(DeviceSpec(s.name, s.peak_flops / 4, s.peak_bw / 4,
                            1e-2) for s in declared_specs(devs))
    ir_d = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0)
    ir_s = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0,
                           device_specs=slow)
    assert ir_s.objective() > ir_d.objective()


def test_with_measured_latency_round_trip():
    devs, studs, A = _fleet(), _students(), _graph()
    ir = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0)
    specs = declared_specs(devs)
    ir_m = ir.with_measured_latency(specs).validate()
    np.testing.assert_array_equal(ir_m.latency_nd, ir.latency_nd)
    assert ir_m.device_specs == specs
    # drop_device keeps the spec tuple aligned with the device columns
    dropped = ir_m.drop_device(ir_m.device_names[0]).validate()
    assert len(dropped.device_specs) == dropped.N
    assert dropped.device_specs[0].name == ir_m.device_names[1]


def test_validate_rejects_inconsistent_specs():
    devs, studs, A = _fleet(), _students(), _graph()
    ir = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0)
    bad = tuple(DeviceSpec(s.name, s.peak_flops * 2, s.peak_bw, 0.0)
                for s in declared_specs(devs))
    with pytest.raises(ValueError, match="disagrees"):
        ir.with_(device_specs=bad).validate()
    with pytest.raises(ValueError, match="specs"):
        ir.with_(device_specs=bad[:2]).validate()


def test_from_plan_with_specs_and_to_arrays():
    devs, studs, A = _fleet(), _students(), _graph()
    plan = PL.make_plan(devs, A, studs, d_th=1.0, p_th=0.2, seed=0)
    specs = tuple(DeviceSpec(d.name, 2.0 * d.c_core, 2.0 * d.r_tran, 0.0)
                  for d in devs)
    ir = PlanIR.from_plan(plan, students=studs, devices=devs,
                          device_specs=specs).validate()
    assert ir.latency_source == "measured"
    base = PlanIR.from_plan(plan, students=studs, devices=devs)
    np.testing.assert_allclose(ir.latency_nd, base.latency_nd / 2.0)
    # the Monte-Carlo view inherits the measured arrival times
    arr_m, arr_d = ir.to_arrays(), base.to_arrays()
    np.testing.assert_allclose(arr_m.t, arr_d.t / 2.0)


def test_select_redundancy_consumes_measured_latency():
    from repro.coding.planner import select_redundancy
    devs, studs, A = _fleet(8), _students(), _graph()
    ir_d = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0)
    ir_m = PL.tune_d_th_ir(devs, A, studs, p_th=0.2, seed=0,
                           device_specs=declared_specs(devs))
    out_d = select_redundancy(ir_d, code_k=3)
    out_m = select_redundancy(ir_m, code_k=3)
    # identical inputs → identical mode selection, and the measured specs
    # survive the pass
    assert out_d.redundancy_modes() == out_m.redundancy_modes()
    assert out_m.device_specs == ir_m.device_specs
    assert out_m.objective() == pytest.approx(out_d.objective())


def test_microbench_fit_pipeline():
    from repro.launch.microbench import (BenchSample, fit_host_spec,
                                         fleet_specs_from_microbench,
                                         samples_to_json)
    rng = np.random.default_rng(0)
    true = DeviceSpec("host", 5e9, 8e8, 1e-4)
    samples = [BenchSample(f"op{i}", (i,), f, b, float(true.latency(f, b)))
               for i, (f, b) in enumerate(zip(rng.uniform(1e6, 1e9, 12),
                                              rng.uniform(1e4, 1e7, 12)))]
    spec = fit_host_spec(samples)
    assert spec.peak_flops == pytest.approx(true.peak_flops, rel=1e-6)
    devs = _fleet(4)
    specs = fleet_specs_from_microbench(devs, samples)
    assert len(specs) == 4
    assert max(s.peak_flops for s in specs) == \
        pytest.approx(spec.peak_flops, rel=1e-6)
    art = samples_to_json(samples, spec)
    assert art["spec"]["name"] == "host" and len(art["samples"]) == 12


@pytest.mark.slow
def test_microbench_measures_real_ops():
    from repro.launch.microbench import fit_host_spec, portion_forward_samples
    samples = portion_forward_samples(widths=(8, 32), batches=(16, 128),
                                      repeats=2)
    assert len(samples) == 4
    assert all(s.wall_s > 0 for s in samples)
    assert all(s.flops > 0 for s in samples)
    spec = fit_host_spec(samples)
    assert spec.peak_flops > 0 and spec.peak_bw > 0
