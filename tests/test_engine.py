"""Continuous-batching serving engine: arrival processes, micro-batch
scheduling under the SLO budget, chaos interleaving with live migration,
per-batch RNG streams, and the controller's non-blocking observe hook.
All runs use a deterministic service model — part of the CI fast lane."""
import numpy as np
import pytest

from repro.core import planner as PL
from repro.core.plan_ir import PlanIR, device_matrix, eq1a_latency, student_matrix
from repro.core.scenarios import MMPPArrivals, PoissonArrivals
from repro.core.simulator import FailureModel, make_fleet
from repro.core.assignment import StudentArch
from repro.core.grouping import Device
from repro.runtime.controller import ClusterController
from repro.runtime.engine import (EngineConfig, ServingEngine,
                                  _serial_config, build_demo_server)
from repro.runtime.failures import FailureEvent, FailureInjector


# -- fixtures -----------------------------------------------------------------

def _toy_ir(M=8):
    devs = [Device("a", 1e7, 2e6, 500, 0.3), Device("b", 2e7, 2e6, 500, 0.3),
            Device("c", 1e7, 2e6, 500, 0.3), Device("d", 3e7, 2e6, 500, 0.3)]
    names, dcaps = device_matrix(devs)
    snames, scaps = student_matrix(
        [StudentArch("s", 5e6, 0.6e6, 64, 0.15e6)])
    member = np.array([[1, 1, 0, 0], [0, 0, 1, 1]], bool)
    part = np.zeros((2, M), bool)
    part[0, :M // 2] = True
    part[1, M // 2:] = True
    return PlanIR(names, dcaps, snames, scaps, member, part,
                  np.zeros(2, np.int64), np.arange(2, dtype=np.int64),
                  eq1a_latency(scaps, dcaps), np.zeros((M, M)), 1.0, 0.5)


def _cfg(**kw):
    base = dict(max_batch=8, max_wait=0.01, slo=0.2,
                service_model=(2e-3, 1e-4), input_dim=8, seed=0)
    base.update(kw)
    return EngineConfig(**base)


def _server(ir=None, **kw):
    return build_demo_server(ir or _toy_ir(), feat=8, hidden=16,
                             n_classes=3, seed=0, **kw)


# -- arrival processes --------------------------------------------------------

def test_poisson_arrivals_rate_and_sizes():
    gen = PoissonArrivals(rate=500.0, sizes=(1, 2, 4),
                          size_probs=(0.5, 0.3, 0.2))
    times, sizes = gen.generate(np.random.default_rng(0), 4.0)
    assert abs(len(times) / 4.0 - 500.0) < 50.0      # ~rate·horizon arrivals
    assert (np.diff(times) >= 0).all() and times[-1] < 4.0
    assert set(np.unique(sizes)) <= {1, 2, 4}
    assert 1.0 < sizes.mean() < 3.0                  # heterogeneous mix


def test_poisson_arrivals_deterministic_and_empty():
    gen = PoissonArrivals(rate=100.0)
    a = gen.generate(np.random.default_rng(3), 1.0)
    b = gen.generate(np.random.default_rng(3), 1.0)
    np.testing.assert_array_equal(a[0], b[0])
    t, s = PoissonArrivals(rate=0.0).generate(np.random.default_rng(0), 1.0)
    assert len(t) == 0 and len(s) == 0


def test_mmpp_rejects_zero_dwell():
    with pytest.raises(ValueError, match="dwell"):
        MMPPArrivals(dwell=(0.0, 0.1)).generate(np.random.default_rng(0), 1.0)


def test_mmpp_burstier_than_poisson():
    """Same mean rate, but the MMPP count process must over-disperse."""
    mm = MMPPArrivals(rates=(20.0, 400.0), dwell=(0.5, 0.125))
    rate = mm.mean_rate()
    rng = np.random.default_rng(1)
    t_mm, _ = mm.generate(rng, 50.0)
    t_po, _ = PoissonArrivals(rate).generate(np.random.default_rng(2), 50.0)
    bins = np.arange(0, 50.0, 0.25)

    def dispersion(t):
        c = np.histogram(t, bins)[0]
        return c.var() / max(c.mean(), 1e-9)

    assert abs(len(t_mm) - len(t_po)) < 0.25 * len(t_po)   # same mean load
    assert dispersion(t_po) < 2.0                          # ≈1 for Poisson
    assert dispersion(t_mm) > 3.0 * dispersion(t_po)       # bursty


# -- scheduling ---------------------------------------------------------------

def test_engine_serves_every_request_in_order():
    srv = _server()
    times, sizes = PoissonArrivals(400.0, sizes=(1, 2, 4)).generate(
        np.random.default_rng(0), 0.5)
    rep = ServingEngine(srv, _cfg()).run(times, sizes)
    assert len(rep.records) == len(times)
    for r in rep.records:
        assert np.isfinite(r.t_done)
        assert r.t_arrival <= r.t_dispatch < r.t_done
        assert r.quorum_ok and not r.degraded
    # FIFO: dispatch order follows arrival order
    order = [r.batch_id for r in sorted(rep.records, key=lambda r: r.rid)]
    assert order == sorted(order)
    # conservation: every batch's requests sum to the record count
    assert sum(b.n_requests for b in rep.batches) == len(times)


def test_batch_closes_at_max_batch_under_pressure():
    srv = _server()
    # all requests arrive at t=0 → batches must close full
    times = np.zeros(40)
    rep = ServingEngine(srv, _cfg(max_batch=8)).run(times)
    assert [b.n_requests for b in rep.batches] == [8] * 5
    assert rep.batches[0].t_dispatch == 0.0          # full batch: no wait


def test_batch_closes_at_max_wait_when_scarce():
    srv = _server()
    rep = ServingEngine(srv, _cfg(max_batch=8, max_wait=0.01)).run([0.0, 0.002])
    assert len(rep.batches) == 1 and rep.batches[0].n_requests == 2
    # the batch closed when the OLDEST request had waited max_wait
    assert rep.batches[0].t_dispatch == pytest.approx(0.01)


def test_serial_config_is_per_request():
    srv = _server()
    times = np.sort(np.random.default_rng(0).uniform(0, 0.5, 30))
    rep = ServingEngine(srv, _serial_config(_cfg())).run(times)
    assert all(b.n_requests == 1 for b in rep.batches)


def test_continuous_batching_beats_serial_throughput():
    """Open-loop overload: batching amortizes the per-dispatch alpha, the
    per-request baseline saturates at 1/(alpha+beta)."""
    times = np.sort(np.random.default_rng(0).uniform(0, 0.02, 200))
    rep_b = ServingEngine(_server(), _cfg(max_batch=16)).run(times)
    rep_s = ServingEngine(_server(), _serial_config(_cfg())).run(times)
    thr_b = rep_b.summary()["throughput"]
    thr_s = rep_s.summary()["throughput"]
    assert thr_b > 5.0 * thr_s
    assert rep_b.summary()["p99"] < rep_s.summary()["p99"]


def test_pipeline_depth_overlaps_batches():
    srv = _server()
    times = np.zeros(32)
    rep1 = ServingEngine(srv, _cfg(max_batch=8)).run(times)
    rep2 = ServingEngine(_server(), _cfg(max_batch=8,
                                         pipeline_depth=2)).run(times)
    # two batches in flight → the second dispatches before the first lands
    d1 = [b.t_dispatch for b in rep1.batches]
    d2 = [b.t_dispatch for b in rep2.batches]
    assert d2[1] == d1[0] and d2[1] < rep2.batches[0].t_done
    assert rep2.records[-1].t_done < rep1.records[-1].t_done


def test_engine_deterministic():
    times, sizes = PoissonArrivals(300.0, sizes=(1, 2)).generate(
        np.random.default_rng(5), 0.3)
    s1 = ServingEngine(_server(), _cfg()).run(times, sizes).summary()
    s2 = ServingEngine(_server(), _cfg()).run(times, sizes).summary()
    assert s1 == s2


# -- per-batch RNG streams ----------------------------------------------------

def test_engine_preserves_server_failure_model():
    """Without a chaos source or an explicit failure_for, the engine must
    serve under the server's OWN failure model, not silently replace it."""
    srv = _server()
    flaky = FailureModel(crash_prob=0.9, outages=False)
    srv.failure = flaky
    rep = ServingEngine(srv, _cfg()).run(np.linspace(0, 0.3, 30))
    assert srv.failure is flaky                      # not clobbered
    assert rep.summary()["quorum_rate"] < 1.0        # the model actually ran


def test_per_batch_rng_streams_reproducible_and_distinct():
    ir = _toy_ir()
    flaky = FailureModel(crash_prob=0.4, outages=False)

    def run(seed):
        srv = _server(ir)
        eng = ServingEngine(srv, _cfg(seed=seed),
                            failure_for=lambda down: flaky)
        return eng.run(np.linspace(0, 0.5, 60))

    a, b, c = run(0), run(0), run(1)
    assert [r.quorum_ok for r in a.records] == [r.quorum_ok for r in b.records]
    assert [r.quorum_ok for r in a.records] != [r.quorum_ok for r in c.records]
    assert any(not r.quorum_ok for r in a.records)     # chaos actually bites
    assert len({r.batch_id for r in a.records if not r.quorum_ok}) > 1


# -- chaos + live migration ---------------------------------------------------

def test_chaos_migration_mid_stream():
    """Kill both replicas of group 0 mid-stream: the controller repairs via
    its non-blocking hook, queued requests pick up the new plan epoch, and
    quorum holds once the repair lands."""
    ir = _toy_ir()
    srv = _server(ir)
    injector = FailureInjector([FailureEvent(1, "a"), FailureEvent(1, "b")])
    ctl = ClusterController(ir, server=srv, injector=injector, seed=0)
    times = np.linspace(0, 0.4, 40)
    cfg = _cfg(max_batch=4, chaos_every=0.1)
    rep = ServingEngine(srv, cfg, controller=ctl).run(times)
    assert len(rep.migrations) == 1
    t_mig, out = rep.migrations[0]
    assert out.kind == "repair"
    epochs = [r.plan_epoch for r in rep.records]
    assert epochs[0] == 0 and epochs[-1] == 1       # migration mid-stream
    after = [r for r in rep.records if r.plan_epoch == 1]
    assert after and all(r.quorum_ok for r in after)
    # the server followed the controller onto the repaired plan
    assert srv.ir is ctl.ir


def test_rerun_resets_per_run_metrics():
    """A second run() on the same engine must not inherit the first run's
    migrations or plan epochs in its report."""
    ir = _toy_ir()
    srv = _server(ir)
    injector = FailureInjector([FailureEvent(1, "a"), FailureEvent(1, "b")])
    ctl = ClusterController(ir, server=srv, injector=injector, seed=0)
    eng = ServingEngine(srv, _cfg(max_batch=4, chaos_every=0.1),
                        controller=ctl)
    rep1 = eng.run(np.linspace(0, 0.4, 40))
    assert len(rep1.migrations) == 1
    rep2 = eng.run(np.linspace(0, 0.1, 10))          # no new chaos events
    assert rep2.migrations == [] and rep2.summary()["migrations"] == 0
    assert all(r.plan_epoch == 0 for r in rep2.records)


def test_chaos_without_controller_degrades():
    ir = _toy_ir()
    srv = _server(ir)
    injector = FailureInjector([FailureEvent(1, "a"), FailureEvent(1, "b")])
    rep = ServingEngine(srv, _cfg(max_batch=4, chaos_every=0.1),
                        injector=injector).run(np.linspace(0, 0.4, 40))
    assert rep.migrations == []
    late = [r for r in rep.records if r.t_dispatch > 0.2]
    assert late and all(not r.quorum_ok and r.degraded for r in late)


def test_in_flight_batch_finishes_on_old_plan():
    """A batch dispatched before the chaos tick keeps its pre-migration
    epoch even though it completes after the repair is applied."""
    ir = _toy_ir()
    srv = _server(ir)
    injector = FailureInjector([FailureEvent(1, "a"), FailureEvent(1, "b")])
    ctl = ClusterController(ir, server=srv, injector=injector, seed=0)
    # slow service: the t=0 batch is still in flight at the chaos tick
    cfg = _cfg(max_batch=4, max_wait=0.0, service_model=(0.3, 0.0),
               chaos_every=0.1)
    rep = ServingEngine(srv, cfg, controller=ctl).run([0.0, 0.2, 0.25, 0.3])
    first = rep.records[0]
    assert first.plan_epoch == 0 and first.t_done > 0.1
    assert rep.records[-1].plan_epoch == 1


# -- controller non-blocking hook ---------------------------------------------

def test_observe_deferred_defers_until_poll():
    ir = _toy_ir()
    ctl = ClusterController(ir, seed=0)
    assert ctl.observe_deferred(["a", "b"]) is True
    assert ctl.ir is ir and ctl.history == []        # nothing planned yet
    out = ctl.poll()
    assert out is not None and out.kind == "repair"
    assert ctl.down == {"a", "b"}
    assert ctl.poll() is None                        # drained


def test_observe_deferred_coalesces():
    ir = _toy_ir()
    ctl = ClusterController(ir, seed=0)
    ctl.observe_deferred(["a", "b"])
    ctl.observe_deferred([])                         # newest set wins
    assert ctl.poll() is None and ctl.down == set()
    assert ctl.history == []


# -- engine on a planned fleet ------------------------------------------------

def test_engine_on_planned_8_device_fleet():
    students = [StudentArch("small", 5e6, 0.6e6, 64, 0.15e6),
                StudentArch("mid", 2e7, 1.5e6, 64, 0.4e6)]
    rng = np.random.default_rng(0)
    a = np.abs(rng.normal(size=(32, 16)))
    A = 0.5 * ((a.T @ a) + (a.T @ a).T)
    np.fill_diagonal(A, 0)
    fleet = make_fleet(8, seed=0, mem_range=(1.0e6, 4e6))
    ir = PL.tune_d_th_ir(fleet, A, students, p_th=0.3, seed=0)
    srv = build_demo_server(ir, feat=8, hidden=16, n_classes=3, seed=0)
    times, sizes = PoissonArrivals(300.0, sizes=(1, 4)).generate(
        np.random.default_rng(2), 0.3)
    s = ServingEngine(srv, _cfg()).run(times, sizes).summary()
    assert s["n"] == len(times)
    assert s["quorum_rate"] == 1.0 and s["slo_attainment"] == 1.0


# -- SLO admission control ----------------------------------------------------

def test_admission_off_by_default_serves_everything():
    cfg = _cfg(max_batch=2, service_model=(2.0, 0.0), slo=5.0)
    assert cfg.admission is False
    s = ServingEngine(_server(), cfg).run(np.zeros(12)).summary()
    assert s["n"] == 12 and s["rejected"] == 0 and s["admitted"] == 12


def test_admission_sheds_doomed_requests():
    """A burst of 12 against a 2-wide server: once queue wait plus the
    plan's predicted quorum latency exceeds the SLO, the tail is shed —
    and every request actually served then makes its SLO."""
    cfg = _cfg(max_batch=2, service_model=(2.0, 0.0), slo=5.0,
               admission=True)
    rep = ServingEngine(_server(), cfg).run(np.zeros(12))
    s = rep.summary()
    assert s["admitted"] == 4 and s["rejected"] == 8
    assert s["admitted"] + s["rejected"] == 12
    assert s["slo_attainment"] == 1.0
    # rejected requests never reach a batch
    assert all(r.batch_id == -1 and r.t_done == float("inf")
               for r in rep.records if r.rejected)


def test_admission_noop_when_slo_is_loose():
    cfg = _cfg(max_batch=8, service_model=(2.0, 0.0), slo=100.0,
               admission=True)
    s = ServingEngine(_server(), cfg).run(np.zeros(12)).summary()
    assert s["rejected"] == 0 and s["admitted"] == 12


def test_admission_consumes_measured_latency():
    """Slower measured device specs raise ir.objective(), so the same
    arrival trace sheds more load — admission reacts to the microbenched
    numbers, not just the declared capacities."""
    from repro.core.hwspec import DeviceSpec, declared_specs

    ir = _toy_ir()
    devs_specs = tuple(
        DeviceSpec(n, pf, bw, 0.0)
        for n, pf, bw in zip(ir.device_names,
                             ir.device_caps[:, 0], ir.device_caps[:, 2]))
    slow = tuple(DeviceSpec(s.name, s.peak_flops / 8, s.peak_bw / 8, 0.0)
                 for s in devs_specs)
    ir_slow = ir.with_measured_latency(slow)
    assert ir_slow.objective() > ir.objective()

    cfg = _cfg(max_batch=2, service_model=(2.0, 0.0), slo=5.0,
               admission=True)
    base = ServingEngine(_server(ir), cfg).run(np.zeros(12)).summary()
    shed = ServingEngine(_server(ir_slow), cfg).run(np.zeros(12)).summary()
    assert shed["rejected"] > base["rejected"]


# -- degenerate percentile math (empty / single-request tenants) --------------

def test_engine_report_summary_empty_tenant():
    """A tenant that saw zero requests (or completed none) must summarize
    without raising or emitting NaN — fleet aggregation folds these in."""
    from repro.runtime.engine import EngineReport, RequestRecord
    for report in (EngineReport([], [], [], slo=0.5),
                   EngineReport([RequestRecord(0, 0.0, 1, rejected=True)],
                                [], [], slo=0.5)):
        assert report.latencies().shape == (0,)
        s = report.summary()
        assert s["n"] == 0 and s["throughput"] == 0.0
        assert s["p50"] == float("inf") and s["p99"] == float("inf")
        assert not any(isinstance(v, float) and np.isnan(v)
                       for v in s.values())


def test_engine_report_summary_single_request():
    """p50/p99 of a one-request tenant are that request's latency — never
    NaN, never an interpolation artifact."""
    from repro.runtime.engine import EngineReport, RequestRecord
    r = RequestRecord(0, 1.0, 1, t_dispatch=1.01, t_done=1.05,
                      quorum_ok=True)
    s = EngineReport([r], [], [], slo=0.5).summary()
    assert s["n"] == 1
    assert s["p50"] == pytest.approx(r.latency)
    assert s["p99"] == pytest.approx(r.latency)
    assert s["slo_attainment"] == 1.0
    assert not any(isinstance(v, float) and np.isnan(v)
                   for v in s.values())
    # throughput guards its zero-width time window instead of dividing by 0
    assert np.isfinite(s["throughput"])
