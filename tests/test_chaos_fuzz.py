"""Chaos fuzz regression (robustness satellite): random aliveness matrices —
including beyond-quorum-distance patterns and all-dead rows — must produce
bit-identical results on the fused megastep vs the legacy per-slot loop, and
must never emit a NaN. Seeded and CPU-light — CI fast lane."""
import numpy as np
import pytest

from repro.core.assignment import StudentArch
from repro.core.grouping import Device
from repro.core.plan_ir import (PlanIR, device_matrix, eq1a_latency,
                                student_matrix)
from repro.runtime.engine import build_demo_server


def _toy_ir(M=8):
    devs = [Device("a", 1e7, 2e6, 500, 0.3), Device("b", 2e7, 2e6, 500, 0.3),
            Device("c", 1e7, 2e6, 500, 0.3), Device("d", 3e7, 2e6, 500, 0.3)]
    names, dcaps = device_matrix(devs)
    snames, scaps = student_matrix([StudentArch("s", 5e6, 0.6e6, 64, 0.15e6)])
    member = np.array([[1, 1, 0, 0], [0, 0, 1, 1]], bool)
    part = np.zeros((2, M), bool)
    part[0, :M // 2] = True
    part[1, M // 2:] = True
    return PlanIR(names, dcaps, snames, scaps, member, part,
                  np.zeros(2, np.int64), np.arange(2, dtype=np.int64),
                  eq1a_latency(scaps, dcaps), np.zeros((M, M)), 1.0, 0.5)


class _ScriptedAlive:
    """A failure scenario that replays pre-drawn aliveness matrices verbatim
    — the fuzzer's way of forcing the SAME chaos onto two servers. Matches
    the scenario interface ``sample(rng, arrays, trials) -> (alive, delay)``;
    the rng is deliberately ignored."""

    deadline = None

    def __init__(self, matrices):
        self._queue = list(matrices)

    def sample(self, rng, arrays, trials):
        alive = self._queue.pop(0)
        assert alive.shape == (trials, len(arrays.names))
        return alive, None


def _chaos_matrices(rng, n_batches, rows_per_batch, n_devices):
    """Random aliveness, biased to include the nasty corners: per-slot
    wipeouts, all-dead rows, and all-alive rows."""
    out = []
    for _ in range(n_batches):
        alive = rng.random((rows_per_batch, n_devices)) > rng.uniform(0.1, 0.9)
        r = rng.integers(0, rows_per_batch)
        alive[r] = False                       # beyond any quorum distance
        if rows_per_batch > 1:
            alive[(r + 1) % rows_per_batch] = True
        out.append(alive)
    return out


def _pair():
    ir = _toy_ir()
    build = dict(feat=8, hidden=16, n_classes=3, seed=0)
    return (build_demo_server(ir, **build),
            build_demo_server(ir, fastpath=False, **build))


def _x(rows, seed):
    return np.random.default_rng(seed).normal(size=(rows, 8)).astype(
        np.float32)


@pytest.mark.parametrize("trial", range(6))
def test_fuzz_fused_matches_legacy_and_never_nan(trial):
    fused, legacy = _pair()
    assert fused.fastpath_active and not legacy.fastpath_active
    rng = np.random.default_rng(1000 + trial)
    xs = [_x(int(rng.integers(1, 6)), seed=trial * 100 + i)
          for i in range(int(rng.integers(2, 5)))]
    # ONE matrix per serve_batch call, one row per request: random chaos
    # plus the corners — an all-dead row next to a failure-free row
    matrix = rng.random((len(xs), 4)) > 0.5
    matrix[0] = False                          # all devices dead for req 0
    if len(xs) > 1:
        matrix[1] = True                       # failure-free row alongside
    fused.failure = _ScriptedAlive([matrix.copy()])
    legacy.failure = _ScriptedAlive([matrix.copy()])
    rf = fused.serve_batch(xs, rng=np.random.default_rng(trial))
    rl = legacy.serve_batch(xs, rng=np.random.default_rng(trial))
    for a, b in zip(rf, rl):
        np.testing.assert_array_equal(a.logits, b.logits)
        assert np.isfinite(a.logits).all(), "fused path emitted non-finite"
        assert np.isfinite(b.logits).all(), "legacy path emitted non-finite"
        assert (a.arrived == b.arrived).all()
        assert a.degraded == b.degraded


def test_fuzz_many_batches_sequenced():
    """A stream of chaotic batches through long-lived servers: the scripted
    scenario replays the identical matrix sequence to both, results must
    stay bit-identical batch after batch."""
    fused, legacy = _pair()
    rng = np.random.default_rng(77)
    rows = 3
    mats = _chaos_matrices(rng, 8, rows, 4)
    fused.failure = _ScriptedAlive([m.copy() for m in mats])
    legacy.failure = _ScriptedAlive([m.copy() for m in mats])
    for b in range(8):
        # `rows` requests per batch: one scripted matrix row per request
        xs = [_x(2, seed=b * 7 + i) for i in range(rows)]
        ra = fused.serve_batch(xs, rng=np.random.default_rng(b))
        ro = legacy.serve_batch(xs, rng=np.random.default_rng(b))
        for a, o in zip(ra, ro):
            np.testing.assert_array_equal(a.logits, o.logits)
            assert np.isfinite(a.logits).all() and np.isfinite(o.logits).all()
            assert (a.arrived == o.arrived).all()


def test_all_dead_row_is_defined_not_nan():
    """Every portion missing (beyond any quorum distance) must yield the
    FC bias — a defined degraded answer — on BOTH paths, never 0/0."""
    fused, legacy = _pair()
    dead = np.zeros((1, 4), bool)
    fused.failure = _ScriptedAlive([dead.copy()])
    legacy.failure = _ScriptedAlive([dead.copy()])
    x = _x(3, seed=2)
    a = fused.serve_batch([x], rng=np.random.default_rng(0))[0]
    b = legacy.serve_batch([x], rng=np.random.default_rng(0))[0]
    assert not a.arrived.any() and a.degraded
    np.testing.assert_array_equal(a.logits, b.logits)
    assert np.isfinite(a.logits).all()
    np.testing.assert_allclose(
        a.logits, np.broadcast_to(np.asarray(fused.fc_bias), (3, 3)),
        rtol=1e-6)
