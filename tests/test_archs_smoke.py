"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import tiny_version
from repro.configs.base import all_archs
from repro.models import api

ARCHS = sorted(all_archs())


def _batch(cfg, B=2, S=32):
    key = jax.random.key(1)
    bd = {}
    if cfg.embed_inputs:
        bd["embeds"] = 0.1 * jax.random.normal(key, (B, S, cfg.d_model),
                                               cfg.compute_dtype)
        if cfg.family == "encdec":
            bd["tokens"] = jnp.zeros((B, S), jnp.int32)
        if cfg.pos == "mrope":
            bd["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    else:
        bd["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    bd["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return bd


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = tiny_version(all_archs()[arch])
    params = api.init(jax.random.key(0), cfg)
    B, S = 2, 32
    bd = _batch(cfg, B, S)
    logits = api.forward(params, cfg, bd)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.slow                 # value_and_grad compile per arch
@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = tiny_version(all_archs()[arch])
    params = api.init(jax.random.key(0), cfg)
    bd = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss(p, cfg, bd))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = tiny_version(all_archs()[arch])
    params = api.init(jax.random.key(0), cfg)
    B, S = 2, 16
    cache = api.init_cache(cfg, B, S)
    bd = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache2 = api.decode_step(params, cfg, bd, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structurally unchanged
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.slow                 # compiles prefill + per-token decode
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "jamba-v0.1-52b", "whisper-medium"])
def test_prefill_matches_decode(arch):
    """Prefill-then-decode must equal pure decode token-by-token."""
    cfg = tiny_version(all_archs()[arch])
    params = api.init(jax.random.key(0), cfg)
    B, S = 1, 8
    bd = _batch(cfg, B, S)
    # full forward logits
    full = api.forward(params, cfg, bd)
    if cfg.family == "encdec":
        # decode path consumes decoder tokens; cross-kv from prefill
        logits_p, cache = api.prefill(params, cfg, bd)
        np.testing.assert_allclose(np.asarray(logits_p[:, -1], np.float32),
                                   np.asarray(full[:, -1], np.float32),
                                   rtol=2e-2, atol=2e-2)
        return
    # token-by-token decode must reproduce the full-sequence logits
    cache = api.init_cache(cfg, B, S)
    toks = bd.get("tokens")
    if toks is None:
        return
    outs = []
    for t in range(S):
        dbd = {"tokens": toks[:, t:t + 1]}
        lg, cache = api.decode_step(params, cfg, dbd, cache, jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)
