"""Unit tests for the RoCoIn core: activation graph, Ncut, grouping,
Hungarian assignment, planner, simulator."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import activation_graph as AG
from repro.core import assignment as ASG
from repro.core import grouping as GRP
from repro.core import ncut as NC
from repro.core import planner as PL
from repro.core import simulator as SIM
from repro.core.assignment import StudentArch
from repro.core.grouping import Device


def _fleet(n=8, seed=0):
    return SIM.make_fleet(n, seed=seed)


def _students():
    return [
        StudentArch("small", flops=5e6, params=0.6e6, out_bytes=64, capacity=0.15e6),
        StudentArch("mid", flops=20e6, params=1.5e6, out_bytes=64, capacity=0.4e6),
        StudentArch("big", flops=50e6, params=3.5e6, out_bytes=64, capacity=1.2e6),
    ]


def _graph(M=32, seed=0):
    rng = np.random.default_rng(seed)
    acts = np.abs(rng.normal(size=(64, M))).astype(np.float32)
    return np.asarray(AG.activation_graph(jnp.asarray(acts)))


# -- activation graph ---------------------------------------------------------

def test_activation_graph_symmetric_nonneg_zero_diag():
    A = _graph()
    assert np.allclose(A, A.T)
    assert (A >= 0).all()
    assert np.allclose(np.diag(A), 0)


def test_average_activity_shapes():
    fm = jnp.ones((4, 8, 8, 16))
    a = AG.average_activity(fm)
    assert a.shape == (4, 16)
    a2 = AG.average_activity(jnp.ones((4, 10, 16)))
    assert a2.shape == (4, 16)


# -- ncut ---------------------------------------------------------------------

def test_ncut_partition_covers_disjoint():
    A = _graph(M=24)
    parts = NC.ncut_partition(A, 4)
    allidx = np.concatenate(parts)
    assert sorted(allidx.tolist()) == list(range(24))
    assert len(allidx) == len(set(allidx.tolist()))


def test_ncut_separates_two_blocks():
    """Two dense blocks with weak cross edges → Ncut must find them."""
    M = 16
    A = np.full((M, M), 0.01)
    A[:8, :8] = 1.0
    A[8:, 8:] = 1.0
    np.fill_diagonal(A, 0)
    parts = NC.ncut_partition(A, 2)
    sets = [set(p.tolist()) for p in parts]
    assert {frozenset(range(8)), frozenset(range(8, 16))} == \
           {frozenset(s) for s in sets}


def test_ncut_value_lower_for_good_cut():
    M = 16
    A = np.full((M, M), 0.01)
    A[:8, :8] = 1.0
    A[8:, 8:] = 1.0
    np.fill_diagonal(A, 0)
    good = [np.arange(8), np.arange(8, 16)]
    bad = [np.arange(0, 16, 2), np.arange(1, 16, 2)]
    assert NC.ncut_value(A, good) < NC.ncut_value(A, bad)


# -- grouping -----------------------------------------------------------------

def test_follow_the_leader_covers_all_devices():
    fleet = _fleet(10)
    g = GRP.follow_the_leader(fleet, d_th=1.0, p_th=0.05)
    names = [d.name for grp in g.groups for d in grp]
    assert sorted(names) == sorted(d.name for d in fleet)
    assert len(names) == len(set(names))          # disjoint (1d)


def test_small_p_th_forces_replication():
    fleet = _fleet(8)
    loose = GRP.follow_the_leader(fleet, d_th=10.0, p_th=0.5)
    strict = GRP.follow_the_leader(fleet, d_th=10.0, p_th=1e-4)
    # stricter reliability target ⇒ need more replicas per group ⇒ fewer groups
    assert strict.K <= loose.K


# -- hungarian ----------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 4, 5])
def test_hungarian_matches_bruteforce(n):
    rng = np.random.default_rng(n)
    W = rng.random((n, n))
    cols = ASG.hungarian(W)
    got = W[np.arange(n), cols].sum()
    best = max(sum(W[i, p[i]] for i in range(n))
               for p in itertools.permutations(range(n)))
    assert np.isclose(got, best)
    assert sorted(cols.tolist()) == list(range(n))  # a permutation


def test_feasible_students_respects_memory():
    fleet = [Device("a", 1e7, 1.0e6, 500, 0.2), Device("b", 2e7, 2.0e6, 500, 0.2)]
    S = _students()
    feas = ASG.feasible_students(fleet, S)
    assert all(s.params <= 1.0e6 for s in feas)


# -- planner ------------------------------------------------------------------

def test_plan_covers_filters_and_devices():
    fleet = _fleet(8, seed=3)
    A = _graph(M=32)
    plan = PL.make_plan(fleet, A, _students(), d_th=2.0, p_th=0.2)
    filt = np.concatenate([g.filters for g in plan.groups])
    assert sorted(filt.tolist()) == list(range(32))         # (1c) + (1e)
    devs = [d.name for g in plan.groups for d in g.devices]
    assert len(devs) == len(set(devs))                      # (1d)


def test_plan_latency_objective_is_max_of_group_latencies():
    fleet = _fleet(8, seed=4)
    A = _graph(M=16)
    plan = PL.make_plan(fleet, A, _students(), d_th=2.0, p_th=0.2)
    if plan.feasible:
        assert plan.latency == max(g.latency for g in plan.groups)


def test_rocoin_beats_nonn_on_straggler_fleet():
    """The paper's central latency claim (Fig. 7): uniform NoNN is
    bottlenecked by a straggler forced to run the common (large) student,
    while heterogeneity-aware assignment gives the straggler a small model."""
    fast = [Device(f"fast{i}", c_core=3e7, c_mem=4e6, r_tran=1e3, p_out=0.1)
            for i in range(7)]
    straggler = [Device("slow", c_core=2e6, c_mem=4e6, r_tran=1e3, p_out=0.1)]
    fleet = fast + straggler
    A = _graph(M=32)
    S = _students()
    nonn = PL.plan_nonn(fleet, A, S)       # everyone gets the big student
    het = PL.plan_hetnonn(fleet, A, S)     # straggler gets a small student
    assert het.latency < nonn.latency
    rocoin = PL.tune_d_th(fleet, A, S, p_th=0.5)
    assert rocoin.latency <= nonn.latency + 1e-9


# -- simulator ----------------------------------------------------------------

def test_simulator_no_failures_completes():
    fleet = [Device(f"d{i}", 1e7, 2e6, 500, 0.0) for i in range(4)]
    A = _graph(M=16)
    plan = PL.make_plan(fleet, A, _students(), d_th=10.0, p_th=1.0)
    res = SIM.simulate(plan, trials=20, failure=SIM.FailureModel())
    assert res["complete_rate"] == 1.0
    assert np.isfinite(res["mean_latency"])


def test_simulator_forced_failures_degrade_coverage():
    fleet = [Device(f"d{i}", 1e7, 2e6, 500, 0.0) for i in range(4)]
    A = _graph(M=16)
    plan = PL.make_plan(fleet, A, _students(), d_th=10.0, p_th=1.0)
    down = [d.name for g in plan.groups for d in g.devices][:2]
    res = SIM.simulate(plan, trials=10,
                       failure=SIM.FailureModel(forced_failures=down))
    assert res["mean_coverage"] < 1.0


def test_replication_improves_failure_resilience():
    """Core paper claim: replicated groups survive crashes better."""
    fleet = [Device(f"d{i}", 1e7 + i, 2e6, 500, 0.45) for i in range(8)]
    A = _graph(M=16)
    S = _students()
    replicated = PL.make_plan(fleet, A, S, d_th=100.0, p_th=0.25)  # forces groups
    solo = PL.plan_hetnonn(fleet, A, S)
    fm = SIM.FailureModel(crash_prob=0.3)
    r1 = SIM.simulate(replicated, trials=200, seed=1, failure=fm)
    r2 = SIM.simulate(solo, trials=200, seed=1, failure=fm)
    assert r1["mean_coverage"] > r2["mean_coverage"]
