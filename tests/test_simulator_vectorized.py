"""Vectorized Monte-Carlo engine: fixed-seed equivalence against the seed
per-trial implementation, plus unit coverage for each failure scenario
(correlated domains, straggler deadlines, Markov link flapping) and the
batched quorum server."""
import numpy as np
import pytest

from repro.core import planner as PL
from repro.core import simulator as SIM
from repro.core.assignment import StudentArch
from repro.core.grouping import Device
from repro.core.scenarios import (CorrelatedFailures, MarkovLinkScenario,
                                  ScheduledScenario, StragglerScenario)
from repro.core.simulator import FailureModel
from repro.runtime.failures import (FailureEvent, FailureInjector,
                                    markov_flap_schedule)


def _graph(m=24, seed=0):
    rng = np.random.default_rng(seed)
    A = np.abs(rng.normal(size=(m, m)))
    A = 0.5 * (A + A.T)
    np.fill_diagonal(A, 0)
    return A


def _students():
    return [
        StudentArch("small", flops=5e6, params=0.6e6, out_bytes=64, capacity=0.15e6),
        StudentArch("mid", flops=2e7, params=1.5e6, out_bytes=64, capacity=0.4e6),
        StudentArch("big", flops=5e7, params=3.5e6, out_bytes=64, capacity=1.2e6),
    ]


def _plan(n=8, seed=2, d_th=2.0, p_th=0.3):
    fleet = SIM.make_fleet(n, seed=seed)
    return PL.make_plan(fleet, _graph(), _students(), d_th=d_th, p_th=p_th)


# -- fixed-seed equivalence vs the seed per-trial loop ------------------------

@pytest.mark.parametrize("failure", [
    FailureModel(),                                        # Rayleigh outages
    FailureModel(outages=False),                           # deterministic
    FailureModel(forced_failures=["d0", "d3"]),            # forced downs
    FailureModel(crash_prob=0.3, outages=False),           # crashes only
], ids=["outages", "none", "forced", "crash"])
def test_vectorized_matches_loop_bitforbit(failure):
    """Whenever the legacy RNG draw count is shape-deterministic, the
    vectorized engine consumes the stream identically → results are
    bit-for-bit equal at a fixed seed."""
    plan = _plan()
    for seed in (0, 7, 42):
        vec = SIM.simulate(plan, trials=300, seed=seed, failure=failure)
        loop = SIM.simulate(plan, trials=300, seed=seed, failure=failure,
                            engine="loop")
        assert vec == loop


def test_vectorized_matches_loop_statistically_crash_and_outage():
    """crash_prob > 0 with outages makes the legacy draw count data-dependent
    (crashed devices skip their outage draw), so the vectorized sampler uses
    a decoupled two-matrix protocol: identical distribution, different
    stream layout. Check agreement at Monte-Carlo resolution."""
    plan = _plan()
    failure = FailureModel(crash_prob=0.2)
    vec = SIM.simulate(plan, trials=20_000, seed=0, failure=failure)
    loop = SIM.simulate(plan, trials=20_000, seed=1, failure=failure,
                        engine="loop")
    assert abs(vec["mean_coverage"] - loop["mean_coverage"]) < 0.02
    assert abs(vec["complete_rate"] - loop["complete_rate"]) < 0.02
    assert abs(vec["mean_latency"] - loop["mean_latency"]) < 0.05


def test_accuracy_under_failures_matches_seed_loop():
    plan = _plan()

    def acc_fn(arrived):
        return float(arrived.mean() * 0.9 + 0.05)

    got = SIM.accuracy_under_failures(plan, acc_fn, n_failed=3, trials=50,
                                      seed=5)
    # the seed implementation, inlined as the oracle
    rng = np.random.default_rng(5)
    all_devices = [d.name for g in plan.groups for d in g.devices]
    accs = []
    for _ in range(50):
        down = set(rng.choice(all_devices, size=min(3, len(all_devices)),
                              replace=False))
        arrived = np.zeros(plan.K, bool)
        for slot, g in enumerate(plan.groups):
            arrived[slot] = any(d.name not in down for d in g.devices)
        accs.append(acc_fn(arrived))
    assert got == float(np.mean(accs))


def test_simulate_trial_shim_unchanged():
    plan = _plan()
    rng = np.random.default_rng(3)
    r = SIM.simulate_trial(plan, rng, FailureModel())
    assert r.arrived.shape == (plan.K,)
    assert r.coverage == float(r.arrived.mean())
    assert np.isfinite(r.latency) or not r.arrived.any()


# -- failure scenarios --------------------------------------------------------

def _reliable_plan():
    fleet = [Device(f"d{i}", 1e7, 2e6, 500, 0.0) for i in range(8)]
    return PL.make_plan(fleet, _graph(16), _students(), d_th=10.0, p_th=1.0)


def test_correlated_domain_blackout_kills_all_members():
    plan = _reliable_plan()
    names = [d.name for g in plan.groups for d in g.devices]
    sc = CorrelatedFailures(domains={"all": names}, domain_fail_prob=1.0,
                            base=FailureModel(outages=False))
    res = SIM.simulate(plan, trials=50, seed=0, failure=sc)
    assert res["mean_coverage"] == 0.0
    assert res["mean_latency"] == float("inf")


def test_correlated_partial_domains_match_bernoulli_rate():
    plan = _reliable_plan()
    names = [d.name for g in plan.groups for d in g.devices]
    sc = CorrelatedFailures(domains={"rack": names}, domain_fail_prob=0.25,
                            base=FailureModel(outages=False))
    res = SIM.simulate(plan, trials=20_000, seed=1, failure=sc)
    # whole fleet blacks out together → complete_rate = P(domain up)
    assert abs(res["complete_rate"] - 0.75) < 0.02
    assert res["mean_coverage"] == res["complete_rate"]


def test_straggler_delay_inflates_latency_and_deadline_drops():
    plan = _reliable_plan()
    base = FailureModel(outages=False)
    clean = SIM.simulate(plan, trials=2000, seed=0, failure=base)
    slow = SIM.simulate(plan, trials=2000, seed=0,
                        failure=StragglerScenario(base=base))
    assert slow["mean_latency"] > clean["mean_latency"]
    assert slow["complete_rate"] == 1.0          # no deadline → all arrive
    dl = clean["mean_latency"] * 1.2
    timed_out = SIM.simulate(plan, trials=2000, seed=0,
                             failure=StragglerScenario(base=base, deadline=dl))
    assert timed_out["mean_coverage"] < 1.0      # some replicas miss quorum
    assert timed_out["mean_latency"] <= dl       # arrivals beat the deadline


def test_straggler_rejects_unknown_dist():
    plan = _reliable_plan()
    with pytest.raises(ValueError):
        SIM.simulate(plan, trials=4, seed=0,
                     failure=StragglerScenario(dist="pareto"))


def test_markov_flapping_coverage_between_extremes():
    plan = _reliable_plan()
    base = FailureModel(outages=False)
    never = SIM.simulate(plan, trials=2000, seed=0,
                         failure=MarkovLinkScenario(p_fail=0.0, base=base))
    flappy = SIM.simulate(plan, trials=2000, seed=0,
                          failure=MarkovLinkScenario(p_fail=0.3, p_recover=0.3,
                                                     base=base))
    assert never["mean_coverage"] == 1.0
    assert 0.0 < flappy["mean_coverage"] < 1.0


def test_markov_stationary_up_fraction():
    """Gilbert chain stationary up-probability = p_r / (p_f + p_r)."""
    rng = np.random.default_rng(0)
    names = [f"d{i}" for i in range(20)]
    ev = markov_flap_schedule(names, p_fail=0.1, p_recover=0.3, ticks=5000,
                              rng=rng)
    up = FailureInjector(ev).alive_matrix(names, 5000)
    assert abs(up[1000:].mean() - 0.75) < 0.03


def test_injector_alive_matrix_matches_tick_replay():
    events = [FailureEvent(2, "a"), FailureEvent(4, "b"),
              FailureEvent(6, "a", "recover"), FailureEvent(6, "c"),
              FailureEvent(9, "c", "recover")]
    names = ["a", "b", "c"]
    mat = FailureInjector(list(events)).alive_matrix(names, 12)
    inj = FailureInjector(list(events))
    for t in range(12):
        down = inj.tick()
        assert (mat[t] == np.array([n not in down for n in names])).all()


def test_scheduled_scenario_replays_chaos_script():
    plan = _reliable_plan()
    names = [d.name for g in plan.groups for d in g.devices]
    inj = FailureInjector([FailureEvent(0, n) for n in names]
                          + [FailureEvent(5, n, "recover") for n in names])
    res = SIM.simulate(plan, trials=10, seed=0,
                       failure=ScheduledScenario(inj))
    # down for ticks 0–4, up for 5–9 → half the trials complete
    assert res["complete_rate"] == 0.5


def test_scheduled_scenario_sequential_batches_continue_script():
    """Two 5-request batches must consume ticks 0–4 then 5–9, matching the
    per-request tick() flow — not restart the chaos script."""
    plan = _reliable_plan()
    names = [d.name for g in plan.groups for d in g.devices]
    inj = FailureInjector([FailureEvent(0, n) for n in names]
                          + [FailureEvent(5, n, "recover") for n in names])
    sc = ScheduledScenario(inj)
    arrays = SIM.plan_arrays(plan)
    rng = np.random.default_rng(0)
    first, _ = sc.sample(rng, arrays, 5)     # ticks 0–4: everyone down
    second, _ = sc.sample(rng, arrays, 5)    # ticks 5–9: everyone up
    assert not first.any()
    assert second.all()


def test_injector_alive_matrix_start_offset():
    events = [FailureEvent(2, "a"), FailureEvent(6, "a", "recover")]
    names = ["a", "b"]
    full = FailureInjector(list(events)).alive_matrix(names, 10)
    windowed = FailureInjector(list(events)).alive_matrix(names, 6, start=4)
    assert (windowed == full[4:10]).all()


# -- batched quorum serving ---------------------------------------------------

def _toy_server(failure):
    import jax.numpy as jnp
    from repro.runtime.serving import QuorumServer
    st = StudentArch("s", 5e6, 0.6e6, 64, 0.15e6)
    groups = [
        PL.GroupPlan(0, [Device("a", 1e7, 2e6, 500, 0.3),
                         Device("b", 2e7, 2e6, 500, 0.3)], 0,
                     np.arange(4), st),
        PL.GroupPlan(1, [Device("c", 1e7, 2e6, 500, 0.3),
                         Device("d", 3e7, 2e6, 500, 0.3)], 1,
                     np.arange(4, 8), st),
    ]
    plan = PL.Plan(groups, np.zeros((8, 8)), 1.0, 0.5)
    Dk, C = 4, 3
    W = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, Dk, C)).astype(np.float32))
    b = jnp.asarray(np.arange(C, dtype=np.float32))
    fns = [lambda x: x @ jnp.ones((x.shape[-1], Dk), jnp.float32),
           lambda x: x @ (2 * jnp.ones((x.shape[-1], Dk), jnp.float32))]
    return QuorumServer(plan, fns, W, b, failure=failure)


def test_serve_batch_equals_individual_serves():
    import jax.numpy as jnp
    srv = _toy_server(FailureModel(outages=False))
    ref = _toy_server(FailureModel(outages=False))
    xs = [jnp.asarray(np.random.default_rng(i).normal(
        size=(3, 5)).astype(np.float32)) for i in range(4)]
    batch = srv.serve_batch(xs)
    for x, r in zip(xs, batch):
        single = ref.serve(x)
        np.testing.assert_allclose(r.logits, single.logits, atol=1e-6)
        assert r.latency == single.latency
        assert (r.arrived == single.arrived).all()
        assert not r.degraded


def test_serve_batch_per_request_degradation():
    import jax.numpy as jnp
    srv = _toy_server(FailureModel(forced_failures=["a", "b"], outages=False))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(3, 5)).astype(np.float32))
    r = srv.serve(x)
    manual = np.asarray(srv.portion_fns[1](x) @ srv.fc_weights[1]
                        + srv.fc_bias)
    np.testing.assert_allclose(r.logits, manual, atol=1e-5)
    assert r.degraded and list(r.arrived) == [False, True]
    assert set(r.failed_devices) == {"a", "b"}


def test_serve_batch_all_down_is_bias_only():
    import jax.numpy as jnp
    srv = _toy_server(FailureModel(forced_failures=["a", "b", "c", "d"]))
    x = jnp.asarray(np.ones((2, 5), np.float32))
    r = srv.serve(x)
    np.testing.assert_allclose(
        r.logits, np.broadcast_to(np.asarray(srv.fc_bias), (2, 3)), atol=1e-6)
    assert not np.isfinite(r.latency) and not r.arrived.any()


def test_server_jits_portions_once():
    srv = _toy_server(FailureModel(outages=False))
    first = srv.jitted_portions
    assert srv.jitted_portions is first          # compiled once, reused
    assert len(first) == srv.plan.K
