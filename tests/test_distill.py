"""Tests for the KD+AT losses (Eq. 6) and the aggregation path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distill as DS

pytestmark = pytest.mark.slow     # distillation training loops


def test_at_loss_zero_for_identical():
    f = jax.random.normal(jax.random.key(0), (4, 16))
    assert float(DS.at_loss(f, f)) < 1e-10
    assert float(DS.at_loss(f, 3.0 * f)) < 1e-10  # scale-invariant (normalized)


def test_at_loss_positive_for_different():
    k1, k2 = jax.random.split(jax.random.key(1))
    a = jax.random.normal(k1, (4, 16))
    b = jax.random.normal(k2, (4, 16))
    assert float(DS.at_loss(a, b)) > 0.01


def test_kd_loss_minimized_by_teacher_match():
    cfg = DS.DistillConfig(alpha=1.0, temperature=2.0)
    t = jax.random.normal(jax.random.key(2), (8, 10))
    labels = jnp.argmax(t, -1)
    matched = float(DS.kd_loss(t, t, labels, cfg))
    off = float(DS.kd_loss(jnp.roll(t, 1, axis=-1), t, labels, cfg))
    assert matched < off


def test_kd_loss_alpha_blends():
    t = jax.random.normal(jax.random.key(3), (8, 10))
    s = jax.random.normal(jax.random.key(4), (8, 10))
    labels = jnp.argmax(t, -1)
    hard_only = DS.kd_loss(s, t, labels, DS.DistillConfig(alpha=0.0))
    soft_only = DS.kd_loss(s, t, labels, DS.DistillConfig(alpha=1.0))
    mid = DS.kd_loss(s, t, labels, DS.DistillConfig(alpha=0.5))
    lo, hi = sorted([float(hard_only), float(soft_only)])
    assert lo - 1e-5 <= float(mid) <= hi + 1e-5


def test_aggregate_portions_zero_fills_missing():
    p0 = jnp.ones((2, 3))
    agg = DS.aggregate_portions([p0, None], [3, 5])
    assert agg.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(agg[:, 3:]), 0.0)


def test_aggregate_portions_all_missing_raises():
    with pytest.raises(ValueError):
        DS.aggregate_portions([None, None], [3, 5])


def test_distill_gradient_flows():
    """Eq. 6 must produce nonzero gradients through both terms."""
    cfg = DS.DistillConfig(alpha=0.5, beta=10.0)
    key = jax.random.key(5)
    t_logits = jax.random.normal(key, (4, 10))
    t_feats = jax.random.normal(key, (4, 8))
    labels = jnp.zeros(4, jnp.int32)
    W = {"proj": jax.random.normal(key, (8, 10)), "feat": jnp.eye(8)}

    def loss(w, x):
        feats = x @ w["feat"]
        logits = feats @ w["proj"]
        return DS.distill_loss(logits, feats, t_logits, t_feats, labels, cfg)

    x = jax.random.normal(key, (4, 8))
    g = jax.grad(loss)(W, x)
    assert float(jnp.abs(g["proj"]).sum()) > 0
    assert float(jnp.abs(g["feat"]).sum()) > 0
