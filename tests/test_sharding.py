"""Unit tests for the sharding/spec layer (no multi-device needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.archs import tiny_version
from repro.configs.base import get_config
from repro.parallel import specs as SP
from repro.parallel.sharding import DEFAULT_RULES, axis_rules, resolve_spec


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_spec_drops_missing_axes():
    mesh = _mesh11()
    spec = resolve_spec(("batch", "seq", "heads"), mesh=mesh)
    # "pod" missing from mesh → dropped from the batch tuple
    assert spec == P("data", None, "model")


def test_sanitize_drops_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake a 4-wide model axis via a mesh dict? use real check on axis size 1:
    spec = SP.sanitize_spec(P(None, "model"), (8, 7), mesh)
    assert spec == P(None, "model")  # axis size 1 divides everything


def test_param_specs_rank_consistency():
    from repro.models import api
    mesh = _mesh11()
    for arch in ["tinyllama-1.1b", "mamba2-130m", "jamba-v0.1-52b",
                 "whisper-medium", "moonshot-v1-16b-a3b"]:
        cfg = tiny_version(get_config(arch))
        shapes = jax.eval_shape(lambda: api.init(jax.random.key(0), cfg))
        spec_tree = SP.param_specs(shapes, mesh, cfg=cfg, kind="train")
        flat_specs = jax.tree.leaves(spec_tree,
                                     is_leaf=lambda s: isinstance(s, P))
        flat_shapes = jax.tree.leaves(shapes)
        assert len(flat_specs) == len(flat_shapes)
        for spec, sds in zip(flat_specs, flat_shapes):
            assert len(spec) <= len(sds.shape), (spec, sds.shape)


def test_zero1_no_duplicate_axes():
    mesh = _mesh11()
    sds = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    spec = P("data", None)
    out = SP.zero1_specs(spec, sds, mesh, axis="data")
    used = [a for a in out if a is not None]
    assert len(used) == len(set(used))


def test_attention_kv_fallbacks():
    """kv_heads % model != 0 must not shard wk/wv by head."""
    import re
    from repro.models import api
    cfg = get_config("grok-1-314b").with_(n_layers=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shapes = jax.eval_shape(lambda: api.init(jax.random.key(0), cfg))
    for kind in ("train", "decode"):
        spec_tree = SP.param_specs(shapes, mesh, cfg=cfg, kind=kind)
        flat = jax.tree_util.tree_flatten_with_path(
            spec_tree, is_leaf=lambda s: isinstance(s, P))[0]
        for path, spec in flat:
            ps = SP._path_str(path)
            if re.search(r"(wk|wv)$", ps):
                # head dim (-2) never sharded for grok (kv=8 vs model axis)
                dims = list(spec)
                if len(dims) >= 2:
                    assert dims[-2] is None or dims[-2] != "model"


def test_cache_specs_cover_all_families():
    from repro.configs.base import SHAPES
    from repro.launch import steps as ST
    mesh = _mesh11()
    for arch in ["tinyllama-1.1b", "mamba2-130m", "jamba-v0.1-52b",
                 "whisper-medium"]:
        cfg = get_config(arch).with_(n_layers=get_config(arch).attn_period or 2)
        with axis_rules(dict(DEFAULT_RULES), mesh):
            cs = ST.cache_specs(cfg, SHAPES["decode_32k"], mesh)
        assert jax.tree.leaves(cs,
                               is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_make_rules_seq_shard_for_long_context():
    from repro.configs.base import SHAPES
    from repro.launch import steps as ST
    cfg = get_config("mamba2-130m")
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((1, 4, 1), ("pod", "data", "model"))
    rules = ST.make_rules(cfg, SHAPES["long_500k"], mesh)
    assert rules["batch"] is None           # batch 1 can't fill DP
    assert rules["seq_shard"] == "data"     # SP takes the axis instead
