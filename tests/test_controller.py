"""ClusterController: incremental repair vs full replanning, live
QuorumServer migration, the remove_device regression, and the one-to-one
remap_students fix. All seeded — part of the CI fast lane."""
import numpy as np
import pytest

from repro.core import planner as PL
from repro.core import simulator as SIM
from repro.core.assignment import StudentArch
from repro.core.grouping import Device
from repro.core.plan_ir import PlanIR
from repro.core.simulator import FailureModel
from repro.runtime.controller import ClusterController, RepairOutcome
from repro.runtime.failures import (FailureInjector, markov_flap_schedule,
                                    remap_students)


def _students():
    return [
        StudentArch("small", flops=5e6, params=0.6e6, out_bytes=64, capacity=0.15e6),
        StudentArch("mid", flops=2e7, params=1.5e6, out_bytes=64, capacity=0.4e6),
        StudentArch("big", flops=5e7, params=3.5e6, out_bytes=64, capacity=1.2e6),
    ]


def _graph(m=16, seed=0):
    rng = np.random.default_rng(seed)
    A = np.abs(rng.normal(size=(m, m)))
    A = 0.5 * (A + A.T)
    np.fill_diagonal(A, 0)
    return A


def _fleet(n, seed=2):
    return SIM.make_fleet(n, seed=seed, mem_range=(1.0e6, 4e6))


def _toy_server(failure=None):
    import jax.numpy as jnp
    from repro.runtime.serving import QuorumServer
    st = StudentArch("s", 5e6, 0.6e6, 64, 0.15e6)
    groups = [
        PL.GroupPlan(0, [Device("a", 1e7, 2e6, 500, 0.3),
                         Device("b", 2e7, 2e6, 500, 0.3)], 0,
                     np.arange(4), st),
        PL.GroupPlan(1, [Device("c", 1e7, 2e6, 500, 0.3),
                         Device("d", 3e7, 2e6, 500, 0.3)], 1,
                     np.arange(4, 8), st),
    ]
    plan = PL.Plan(groups, np.zeros((8, 8)), 1.0, 0.5)
    Dk, C = 4, 3
    W = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, Dk, C)).astype(np.float32))
    b = jnp.asarray(np.arange(C, dtype=np.float32))
    fns = [lambda x: x @ jnp.ones((x.shape[-1], Dk), jnp.float32),
           lambda x: x @ (2 * jnp.ones((x.shape[-1], Dk), jnp.float32))]
    return QuorumServer(plan, fns, W, b,
                        failure=failure or FailureModel(outages=False))


# -- remove_device regression (satellite #1) ---------------------------------

def test_remove_device_repairs_instead_of_dead_group():
    """Permanently losing BOTH replicas of a group used to leave its
    partition missing quorum forever; it now routes through controller
    repair and a donor replica restores it."""
    import jax.numpy as jnp
    srv = _toy_server()
    x = jnp.asarray(np.ones((2, 5), np.float32))
    srv.remove_device("a")
    out = srv.remove_device("b")
    assert out is not None and out.kind == "repair"
    assert srv.ir.quorum().all()
    res = srv.serve(x)
    assert res.arrived.all() and not res.degraded
    # the repair moved one replica out of the healthy group, kept quorum there
    assert {n for n in srv.ir.device_names} == {"c", "d"}
    assert srv.ir.member.sum() == 2


def test_remove_device_legacy_flag_preserves_old_behaviour():
    import jax.numpy as jnp
    srv = _toy_server()
    x = jnp.asarray(np.ones((2, 5), np.float32))
    srv.remove_device("a", repair=False)
    srv.remove_device("b", repair=False)
    res = srv.serve(x)
    assert res.degraded and not res.arrived[0]   # the old dead-group hole


def test_remove_device_noop_when_quorum_survives():
    srv = _toy_server()
    out = srv.remove_device("a")
    assert out is not None and out.kind == "noop"
    assert srv.ir.quorum().all()
    assert "a" not in srv.ir.device_names


# -- migration keeps compiled state -------------------------------------------

def test_migrate_reuses_jitted_portions_for_untouched_slots():
    srv = _toy_server()
    jitted_before = list(srv.jitted_portions)
    ir = srv.ir
    # membership-only change (swap the two groups' devices): partitions
    # untouched → no re-jit
    new_member = np.array(ir.member)[::-1]
    stats = srv.migrate(ir.with_(member=new_member))
    assert stats["rejitted_slots"] == ()
    assert srv.jitted_portions[0] is jitted_before[0]
    assert srv.jitted_portions[1] is jitted_before[1]
    # partition change on slot 0 with no weight store: the deployed forward
    # is unchanged so its compiled wrapper is kept (nothing re-jits) — only
    # the now-stale FC slice is zeroed
    new_part = np.array(ir.partition)
    new_part[0] = ~new_part[0]
    stats = srv.migrate(srv.ir.with_(partition=new_part))
    assert stats["rejitted_slots"] == ()
    assert stats["zeroed_slots"] == (0,)
    assert srv.jitted_portions[0] is jitted_before[0]
    assert srv.jitted_portions[1] is jitted_before[1]


# -- remap_students one-to-one fix (satellite #2) -----------------------------

def test_remap_students_is_one_to_one():
    """Greedy max-overlap used to map several new slots to the same old
    student when one old partition dominated the overlaps."""
    st = _students()[0]

    def plan_with_parts(parts):
        groups = [PL.GroupPlan(i, [Device(f"d{i}", 1e7, 2e6, 500, 0.2)], i,
                               np.asarray(p, np.int64), st)
                  for i, p in enumerate(parts)]
        return PL.Plan(groups, np.zeros((8, 8)), 1.0, 0.5)

    old = plan_with_parts([[0, 1, 2, 3, 4, 5], [6], [7]])
    new = plan_with_parts([[0, 1, 2], [3, 4, 5], [6, 7]])
    mapping = remap_students(old, new)
    assert set(mapping.keys()) == {0, 1, 2}
    assert len(set(mapping.values())) == 3       # one-to-one (greedy gave 0,0,x)
    # works on PlanIR inputs too
    mapping_ir = remap_students(PlanIR.from_plan(old), PlanIR.from_plan(new))
    assert mapping_ir == mapping


# -- incremental repair vs full replan (satellite #4 / acceptance) ------------

def _controller_setup(n=24, m=16, p_th=0.3, seed=2):
    A = _graph(m)
    S = _students()
    fleet = _fleet(n, seed=seed)
    ir = PL.tune_d_th_ir(fleet, A, S, p_th=p_th, seed=0)
    assert ir is not None and ir.feasible
    return ir


def test_repair_restores_quorum_and_stays_near_full_replan_objective():
    ir = _controller_setup()
    names = list(ir.device_names)
    events = markov_flap_schedule(names, 0.15, 0.4, 40,
                                  np.random.default_rng(9))
    ctl = ClusterController(ir, injector=FailureInjector(events), seed=0)
    checked = 0
    for _ in range(40):
        down = ctl.injector.tick()
        alive = ctl.ir.alive_mask(down)
        if ctl.ir.quorum(alive).all():
            ctl.down = set(down)
            continue
        rep = ctl.plan_repair(alive)
        full = ctl.plan_full(alive)
        if rep is not None:
            checked += 1
            assert rep.kind == "repair"
            assert rep.ir.quorum(alive).all()          # quorum restored
            assert rep.feasible
            assert rep.rejitted_slots == ()            # partitions untouched
            # Eq. 1a objective within tolerance of the from-scratch replan
            assert rep.objective <= 1.5 * full.objective + 1e-9
            ctl.down = set(down)
            ctl.ir = rep.ir
        else:
            ctl.down = set(down)
            ctl.ir = full.ir
    assert checked >= 3          # the schedule actually exercised repair


def test_repair_is_strictly_cheaper_than_full_replan():
    """Seeded end-to-end acceptance run: under the same Markov-flap schedule
    the repair controller re-jits and redeploys strictly less, and spends
    strictly less planning wall-clock, than forced full replanning."""
    def run(force_full):
        ir = _controller_setup()
        events = markov_flap_schedule(list(ir.device_names), 0.15, 0.4, 60,
                                      np.random.default_rng(17))
        ctl = ClusterController(ir, injector=FailureInjector(events),
                                force_full=force_full, seed=0)
        outs = []
        for _ in range(60):
            o = ctl.step()
            if o is None:
                continue
            outs.append(o)
            # quorum restored under the down-set current at this tick
            assert o.ir.quorum(o.ir.alive_mask(ctl.down)).all()
        assert outs, "schedule produced no quorum losses"
        return ctl, outs

    ctl_r, rep = run(False)
    ctl_f, full = run(True)
    n_repairs = sum(o.kind == "repair" for o in rep)
    assert n_repairs > 0
    assert all(o.feasible for o in rep)
    rejit_r = sum(len(o.rejitted_slots) for o in rep)
    rejit_f = sum(len(o.rejitted_slots) for o in full)
    redeploy_r = sum(o.redeployed for o in rep)
    redeploy_f = sum(o.redeployed for o in full)
    assert rejit_r < rejit_f                    # strictly fewer re-jits
    assert redeploy_r < redeploy_f              # strictly fewer redeployments
    wall_r = sum(o.wall_s for o in rep)
    wall_f = sum(o.wall_s for o in full)
    assert wall_r < wall_f                      # repair is cheaper wall-clock


def test_controller_drives_live_server_under_flapping():
    """Controller + QuorumServer end-to-end: after every applied outcome the
    server answers with full quorum under the current down-set."""
    import jax.numpy as jnp
    from repro.runtime.serving import QuorumServer
    ir = _controller_setup(n=16)
    Kp, Dk, C = ir.K, 4, 3
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(Kp, Dk, C)).astype(np.float32))
    b = jnp.asarray(np.zeros(C, np.float32))
    fns = [(lambda k: lambda x: x @ ((k + 1.0) * jnp.ones(
        (x.shape[-1], Dk), jnp.float32)))(k) for k in range(Kp)]
    srv = QuorumServer(ir, fns, W, b, failure=FailureModel(outages=False))
    events = markov_flap_schedule(list(ir.device_names), 0.12, 0.4, 30,
                                  np.random.default_rng(23))
    ctl = ClusterController(ir, server=srv, injector=FailureInjector(events),
                            seed=0)
    x = jnp.asarray(np.ones((2, 5), np.float32))
    acted = 0
    for _ in range(30):
        out = ctl.step()
        if out is None:
            continue
        acted += 1
        srv.failure = FailureModel(forced_failures=sorted(ctl.down),
                                   outages=False)
        res = srv.serve(x)
        assert res.arrived.all(), f"quorum hole after {out.kind}"
    assert acted > 0
    assert srv.ir is ctl.ir                     # server follows the controller


def test_permanent_loss_sequence_keeps_serving():
    ir = _controller_setup(n=12)
    ctl = ClusterController(ir, seed=0)
    names = list(ir.device_names)
    for victim in names[:4]:
        out = ctl.permanent_loss(victim)
        assert out is not None
        assert victim not in ctl.ir.device_names
        assert ctl.ir.quorum(ctl.ir.alive_mask(ctl.down)).all()


def test_force_full_controller_only_full_replans():
    ir = _controller_setup(n=16)
    events = markov_flap_schedule(list(ir.device_names), 0.2, 0.4, 25,
                                  np.random.default_rng(3))
    ctl = ClusterController(ir, injector=FailureInjector(events),
                            force_full=True, seed=0)
    outs = ctl.run(25)
    assert outs and all(o.kind == "full_replan" for o in outs)
    assert isinstance(outs[0], RepairOutcome)


# -- spare-pool broker: concurrent repairs must not share a spare -------------

def _tenant_ir(prefix, spare_names, p_out=0.7, spare_p_out=0.1):
    """Two-slot tenant plan (4 owned devices) plus shared, UNASSIGNED spare
    columns. Member p_out is chosen so a healthy group cannot donate (one
    remaining replica would breach Eq. 1f), forcing repairs onto spares."""
    from repro.core.plan_ir import device_matrix, eq1a_latency, student_matrix
    devs = [Device(f"{prefix}-a", 1e7, 2e6, 500, p_out),
            Device(f"{prefix}-b", 2e7, 2e6, 500, p_out),
            Device(f"{prefix}-c", 1e7, 2e6, 500, p_out),
            Device(f"{prefix}-d", 3e7, 2e6, 500, p_out)] + \
           [Device(s, 3e7, 2e6, 500, spare_p_out) for s in spare_names]
    names, dcaps = device_matrix(devs)
    snames, scaps = student_matrix(
        [StudentArch("s", 5e6, 0.6e6, 64, 0.15e6)])
    N = len(devs)
    member = np.zeros((2, N), bool)
    member[0, 0] = member[0, 1] = True
    member[1, 2] = member[1, 3] = True
    M = 8
    part = np.zeros((2, M), bool)
    part[0, :4] = True
    part[1, 4:] = True
    return PlanIR(names, dcaps, snames, scaps, member, part,
                  np.zeros(2, np.int64), np.arange(2, dtype=np.int64),
                  eq1a_latency(scaps, dcaps), np.zeros((M, M)), 1.0, 0.5)


class _Broker:
    """Minimal duck-typed spare-pool arbiter (the real one lives in
    runtime/fleet.py): candidates() is the free pool, notify() settles
    claims and enforces cross-tenant exclusivity."""

    def __init__(self, free):
        self.pool = set(free)       # the pool universe: shared spares only
        self.free = set(free)
        self.log = []

    def candidates(self, shard):
        return set(self.free)

    def notify(self, shard, claimed, freed):
        # tenant-owned devices churn through repairs too; only names in the
        # shared pool universe are the broker's business
        claimed, freed = claimed & self.pool, freed & self.pool
        assert claimed <= self.free, f"double-claimed {claimed - self.free}"
        self.free -= claimed
        self.free |= freed
        self.log.append((claimed, set(freed)))


def test_plan_repair_explicit_candidate_set():
    """Spare selection honors the explicit candidate parameter instead of
    recomputing 'alive & unused' internally."""
    ir = _tenant_ir("t", ["spare-0"])
    ctl = ClusterController(ir, seed=0)
    alive = ir.alive_mask({"t-a", "t-b"})
    out = ctl.plan_repair(alive, spare_candidates={"spare-0"})
    assert out is not None and out.moved_devices == ("spare-0",)
    # an empty candidate set must NOT invent a donor from the same column
    assert ctl.plan_repair(alive, spare_candidates=set()) is None


def test_concurrent_repairs_contend_for_one_spare():
    """Regression: two tenant shards repairing at the same tick both used to
    see the shared spare as 'alive & unused' and both claimed it. Through
    the broker, exactly one wins; the loser must not touch the spare."""
    broker = _Broker({"spare-0"})
    ir_a = _tenant_ir("ta", ["spare-0"])
    ir_b = _tenant_ir("tb", ["spare-0"])
    ctl_a = ClusterController(ir_a, seed=0, spare_broker=broker)
    ctl_b = ClusterController(ir_b, seed=0, spare_broker=broker,
                              require_feasible=False)

    # without a broker each shard would grab the spare for itself
    solo = ClusterController(ir_b, seed=0)
    solo_out = solo.observe({"tb-a", "tb-b"})
    assert solo_out is not None and "spare-0" in solo_out.moved_devices

    out_a = ctl_a.observe({"ta-a", "ta-b"})
    assert out_a.kind == "repair" and "spare-0" in out_a.moved_devices
    assert broker.free == set()                 # claim settled immediately

    out_b = ctl_b.observe({"tb-a", "tb-b"})     # same spare, one tick later
    assert "spare-0" not in ClusterController._assigned_names(ctl_b.ir)
    assert "spare-0" not in (out_b.moved_devices if out_b else ())
    # winner keeps it; broker state still exclusive
    assert "spare-0" in ClusterController._assigned_names(ctl_a.ir)
    assert broker.free == set()


def test_apply_plan_releases_spares_back_to_broker():
    """apply_plan (the autoscaler hook) settles the broker symmetrically:
    dropping a claimed spare from the membership frees it for others."""
    broker = _Broker({"spare-0"})
    ir = _tenant_ir("t", ["spare-0"])
    ctl = ClusterController(ir, seed=0, spare_broker=broker)
    out = ctl.observe({"t-a", "t-b"})
    assert "spare-0" in out.moved_devices and broker.free == set()
    # scale back down: clear the spare's column and re-adopt the plan
    member = np.array(ctl.ir.member)
    col = list(ctl.ir.device_names).index("spare-0")
    member[:, col] = False
    member[0, list(ctl.ir.device_names).index("t-a")] = True  # heal original
    scaled = ctl.ir.with_(member=member)
    res = ctl.apply_plan(scaled, kind="scale_down")
    assert res.kind == "scale_down"
    assert broker.free == {"spare-0"}
