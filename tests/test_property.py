"""Hypothesis property-based tests on system invariants."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (dev dependency)")
from hypothesis import given, settings, strategies as st

from repro.core import assignment as ASG
from repro.core import grouping as GRP
from repro.core import ncut as NC
from repro.core import planner as PL
from repro.core import simulator as SIM
from repro.core.assignment import StudentArch
from repro.core.grouping import Device
from repro.optim.compression import (CompressionConfig, compress_grads,
                                     init_state)

SETTINGS = dict(max_examples=20, deadline=None)


# -- grouping invariants -------------------------------------------------------

@given(n=st.integers(2, 16), d_th=st.floats(0.05, 5.0),
       p_th=st.floats(0.01, 0.9), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_grouping_partitions_devices(n, d_th, p_th, seed):
    fleet = SIM.make_fleet(n, seed=seed)
    g = GRP.follow_the_leader(fleet, d_th=d_th, p_th=p_th)
    names = [d.name for grp in g.groups for d in grp]
    assert sorted(names) == sorted(d.name for d in fleet)   # cover + disjoint
    assert all(len(grp) >= 1 for grp in g.groups)


# -- ncut invariants ------------------------------------------------------------

@given(m=st.integers(4, 40), k=st.integers(1, 8), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_ncut_is_a_partition(m, k, seed):
    rng = np.random.default_rng(seed)
    A = np.abs(rng.normal(size=(m, m)))
    A = 0.5 * (A + A.T)
    np.fill_diagonal(A, 0)
    parts = NC.ncut_partition(A, k, seed=seed)
    allidx = np.concatenate([p for p in parts if len(p)]) if parts else []
    assert sorted(np.asarray(allidx).tolist()) == list(range(m))
    assert len(parts) == min(k, m)


# -- hungarian optimality --------------------------------------------------------

@given(n=st.integers(2, 5), seed=st.integers(0, 200))
@settings(**SETTINGS)
def test_hungarian_is_optimal(n, seed):
    rng = np.random.default_rng(seed)
    W = rng.random((n, n))
    cols = ASG.hungarian(W)
    got = W[np.arange(n), cols].sum()
    best = max(sum(W[i, p[i]] for i in range(n))
               for p in itertools.permutations(range(n)))
    assert got >= best - 1e-9


# -- planner invariants -----------------------------------------------------------

@given(n=st.integers(3, 10), m=st.integers(8, 32),
       p_th=st.floats(0.05, 0.8), seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_plan_constraints(n, m, p_th, seed):
    fleet = SIM.make_fleet(n, seed=seed)
    rng = np.random.default_rng(seed)
    A = np.abs(rng.normal(size=(m, m)))
    A = 0.5 * (A + A.T)
    np.fill_diagonal(A, 0)
    students = [
        StudentArch("s", 5e6, 0.6e6, 64, 0.15e6),
        StudentArch("m", 2e7, 1.5e6, 64, 0.4e6),
        StudentArch("l", 5e7, 3.5e6, 64, 1.2e6),
    ]
    plan = PL.make_plan(fleet, A, students, d_th=1.0, p_th=p_th, seed=seed)
    # (1c)+(1e): filters partitioned
    filt = np.concatenate([g.filters for g in plan.groups]) \
        if plan.groups else np.array([])
    assert sorted(filt.tolist()) == list(range(m))
    # (1d): device appears at most once
    devs = [d.name for g in plan.groups for d in g.devices]
    assert len(devs) == len(set(devs))
    # (1g): chosen students fit the min memory of their group
    for g in plan.groups:
        if g.student is not None:
            assert g.student.params <= min(d.c_mem for d in g.devices) + 1e-9


# -- compression error feedback ----------------------------------------------------

@given(scheme=st.sampled_from(["topk", "int8"]),
       seed=st.integers(0, 50), n=st.integers(8, 200))
@settings(**SETTINGS)
def test_error_feedback_conserves_signal(scheme, seed, n):
    """compressed + new_residual == grad + old_residual (no signal loss)."""
    cfg = CompressionConfig(scheme=scheme, topk_ratio=0.1, seed=seed)
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    state = init_state(cfg, g)
    comp, state2 = compress_grads(cfg, g, state)
    lhs = np.asarray(comp["w"]) + np.asarray(state2.residual["w"])
    rhs = np.asarray(g["w"])  # old residual was zero
    np.testing.assert_allclose(lhs, rhs, atol=1e-5, rtol=1e-5)


@given(seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_topk_keeps_largest(seed):
    cfg = CompressionConfig(scheme="topk", topk_ratio=0.25, seed=seed)
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(100,)), jnp.float32)}
    comp, _ = compress_grads(cfg, g, init_state(cfg, g))
    c = np.asarray(comp["w"])
    nz = np.nonzero(c)[0]
    assert 0 < len(nz) <= 26
    # kept entries are the largest-magnitude ones
    thresh = np.sort(np.abs(np.asarray(g["w"])))[-len(nz)]
    assert (np.abs(np.asarray(g["w"]))[nz] >= thresh - 1e-9).all()


# -- simulator monotonicity -----------------------------------------------------------

@given(crash=st.floats(0.0, 0.6), seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_more_crashes_never_help_coverage(crash, seed):
    fleet = [Device(f"d{i}", 1e7, 2e6, 500, 0.1) for i in range(6)]
    rng = np.random.default_rng(seed)
    A = np.abs(rng.normal(size=(12, 12)))
    A = 0.5 * (A + A.T); np.fill_diagonal(A, 0)
    students = [StudentArch("s", 5e6, 0.6e6, 64, 0.15e6)]
    plan = PL.make_plan(fleet, A, students, d_th=10.0, p_th=0.5, seed=seed)
    lo = SIM.simulate(plan, trials=60, seed=seed,
                      failure=SIM.FailureModel(crash_prob=crash))
    hi = SIM.simulate(plan, trials=60, seed=seed,
                      failure=SIM.FailureModel(crash_prob=min(crash + 0.3, 0.95)))
    assert hi["mean_coverage"] <= lo["mean_coverage"] + 0.08  # noise slack


# -- model invariants --------------------------------------------------------------

@given(b=st.integers(1, 3), s=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 10))
@settings(max_examples=8, deadline=None)
def test_causal_lm_is_causal(b, s, seed):
    """Changing future tokens must not change past logits."""
    from repro.configs.archs import tiny_version
    from repro.configs.base import get_config
    from repro.models import api
    cfg = tiny_version(get_config("tinyllama-1.1b"))
    params = api.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, size=(b, s))
    toks2 = toks.copy()
    toks2[:, s // 2:] = rng.integers(0, cfg.vocab, size=(b, s - s // 2))
    l1 = api.forward(params, cfg, {"tokens": jnp.asarray(toks)})
    l2 = api.forward(params, cfg, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[:, :s // 2], np.float32),
                               np.asarray(l2[:, :s // 2], np.float32),
                               atol=1e-4, rtol=1e-4)
